//! Run traces and the paper's evaluation metrics: objective error, total
//! communication cost (TC), total running time, and average consensus
//! violation (ACV). Includes CSV/JSONL writers and empirical CDFs (Fig. 6).

use crate::comm::PhaseClock;
use crate::util::json::Json;
use std::io::Write;
use std::time::Duration;

/// Column header shared by [`Trace::write_csv`] and the streaming CSV sink
/// (`session::CsvSink`), so both emit byte-identical files.
pub const CSV_HEADER: &str = "iter,obj_err,tc_unit,tc_energy,bits,rounds,seconds,acv";

/// One iteration's measurements.
#[derive(Clone, Debug)]
pub struct IterRecord {
    pub iter: usize,
    /// `|Σ_n f_n(θ_n^k) − F*|`.
    pub obj_err: f64,
    /// Cumulative TC under unit link costs (paper Table 1 / Figs 2–5).
    pub tc_unit: f64,
    /// Cumulative TC under the energy model (paper Fig 6–8).
    pub tc_energy: f64,
    /// Cumulative payload bits on the wire (Q-GADMM's headline metric:
    /// `d·b` + range overhead per quantized slot, `64·d` per dense slot).
    pub bits: f64,
    /// Cumulative communication rounds.
    pub rounds: usize,
    /// Cumulative wall-clock compute time.
    pub elapsed: Duration,
    /// Average consensus violation Σ‖θ_n − θ_{n+1}‖₁ / N (0 for
    /// centralized algorithms, which hold one consensus iterate).
    pub acv: f64,
}

impl IterRecord {
    /// One CSV row in the [`CSV_HEADER`] column order.
    pub fn write_csv_row<W: Write>(&self, w: &mut W) -> std::io::Result<()> {
        writeln!(
            w,
            "{},{:.6e},{},{:.6e},{},{},{:.6e},{:.6e}",
            self.iter,
            self.obj_err,
            self.tc_unit,
            self.tc_energy,
            self.bits,
            self.rounds,
            self.elapsed.as_secs_f64(),
            self.acv
        )
    }

    /// Equality on everything deterministic (wall-clock `elapsed` excluded).
    /// Floats compare bitwise so a diverged run's NaN record still equals
    /// its identical re-run — `==` would call two NaN traces different.
    pub fn same_measurements(&self, other: &IterRecord) -> bool {
        self.iter == other.iter
            && self.obj_err.to_bits() == other.obj_err.to_bits()
            && self.tc_unit.to_bits() == other.tc_unit.to_bits()
            && self.tc_energy.to_bits() == other.tc_energy.to_bits()
            && self.bits.to_bits() == other.bits.to_bits()
            && self.rounds == other.rounds
            && self.acv.to_bits() == other.acv.to_bits()
    }
}

/// A complete run of one algorithm on one problem.
#[derive(Clone, Debug)]
pub struct Trace {
    pub algorithm: String,
    pub problem: String,
    pub records: Vec<IterRecord>,
    /// First iteration index at which `obj_err <= target` (if reached).
    pub converged_at: Option<usize>,
    pub target: f64,
    /// Compute-seconds attribution per group-ADMM phase over the whole run
    /// (zero for engines without the head/tail/dual structure). Wall-clock
    /// measurement only — excluded from [`Trace::same_path`], like
    /// [`IterRecord::elapsed`].
    pub phase: PhaseClock,
}

impl Trace {
    pub fn new(algorithm: &str, problem: &str, target: f64) -> Trace {
        Trace {
            algorithm: algorithm.to_string(),
            problem: problem.to_string(),
            records: Vec::new(),
            converged_at: None,
            target,
            phase: PhaseClock::default(),
        }
    }

    pub fn push(&mut self, rec: IterRecord) {
        if self.converged_at.is_none() && rec.obj_err <= self.target {
            self.converged_at = Some(rec.iter);
        }
        self.records.push(rec);
    }

    /// Iterations to reach the target accuracy (Table 1 top).
    pub fn iters_to_target(&self) -> Option<usize> {
        self.converged_at
    }

    /// TC (unit costs) accumulated up to convergence (Table 1 bottom).
    pub fn tc_to_target(&self) -> Option<f64> {
        self.at_convergence().map(|r| r.tc_unit)
    }

    /// Energy-model TC accumulated up to convergence (Fig 6).
    pub fn energy_to_target(&self) -> Option<f64> {
        self.at_convergence().map(|r| r.tc_energy)
    }

    /// Payload bits transmitted up to convergence (the Q-GADMM metric).
    pub fn bits_to_target(&self) -> Option<f64> {
        self.at_convergence().map(|r| r.bits)
    }

    /// Wall time up to convergence.
    pub fn time_to_target(&self) -> Option<Duration> {
        self.at_convergence().map(|r| r.elapsed)
    }

    fn at_convergence(&self) -> Option<&IterRecord> {
        self.converged_at
            .and_then(|k| self.records.iter().find(|r| r.iter == k))
    }

    pub fn final_error(&self) -> f64 {
        self.records.last().map(|r| r.obj_err).unwrap_or(f64::INFINITY)
    }

    /// Downsample to at most `n` records (for plotting/JSON export), always
    /// keeping the first and last.
    pub fn downsample(&self, n: usize) -> Vec<&IterRecord> {
        let len = self.records.len();
        if len <= n || n < 2 {
            return self.records.iter().collect();
        }
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            let idx = i * (len - 1) / (n - 1);
            out.push(&self.records[idx]);
        }
        out.dedup_by_key(|r| r.iter);
        out
    }

    /// CSV export: `iter,obj_err,tc_unit,tc_energy,bits,rounds,seconds,acv`.
    pub fn write_csv<W: Write>(&self, w: &mut W) -> std::io::Result<()> {
        writeln!(w, "{CSV_HEADER}")?;
        for r in &self.records {
            r.write_csv_row(w)?;
        }
        Ok(())
    }

    /// Whether two traces took the same deterministic path: same algorithm,
    /// convergence point, and per-record measurements (wall-clock timing is
    /// the one field allowed to differ). This is the invariant the parallel
    /// sweep runner pins: thread count must not change any trace.
    pub fn same_path(&self, other: &Trace) -> bool {
        self.algorithm == other.algorithm
            && self.problem == other.problem
            && self.converged_at == other.converged_at
            && self.records.len() == other.records.len()
            && self
                .records
                .iter()
                .zip(&other.records)
                .all(|(a, b)| a.same_measurements(b))
    }

    /// JSON summary (downsampled curve + convergence stats).
    pub fn to_json(&self, curve_points: usize) -> Json {
        let curve: Vec<Json> = self
            .downsample(curve_points)
            .into_iter()
            .map(|r| {
                Json::obj()
                    .set("iter", r.iter)
                    .set("obj_err", r.obj_err)
                    .set("tc_unit", r.tc_unit)
                    .set("tc_energy", r.tc_energy)
                    .set("bits", r.bits)
                    .set("seconds", r.elapsed.as_secs_f64())
                    .set("acv", r.acv)
            })
            .collect();
        Json::obj()
            .set("algorithm", self.algorithm.as_str())
            .set("problem", self.problem.as_str())
            .set("target", self.target)
            .set(
                "iters_to_target",
                self.iters_to_target().map(|k| Json::Num(k as f64)).unwrap_or(Json::Null),
            )
            .set(
                "tc_to_target",
                self.tc_to_target().map(Json::Num).unwrap_or(Json::Null),
            )
            .set(
                "bits_to_target",
                self.bits_to_target().map(Json::Num).unwrap_or(Json::Null),
            )
            .set("final_error", self.final_error())
            .set(
                "phase_seconds",
                Json::obj()
                    .set("head", self.phase.head_seconds)
                    .set("tail", self.phase.tail_seconds)
                    .set("dual", self.phase.dual_seconds),
            )
            .set("curve", Json::Arr(curve))
    }
}

/// Empirical CDF over a sample of scalars (Fig. 6a/6b).
#[derive(Clone, Debug)]
pub struct Cdf {
    /// Sorted sample values.
    pub values: Vec<f64>,
}

impl Cdf {
    pub fn from_samples(mut samples: Vec<f64>) -> Cdf {
        samples.retain(|v| v.is_finite());
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Cdf { values: samples }
    }

    /// P(X ≤ x).
    pub fn at(&self, x: f64) -> f64 {
        if self.values.is_empty() {
            return f64::NAN;
        }
        let count = self.values.partition_point(|&v| v <= x);
        count as f64 / self.values.len() as f64
    }

    /// Inverse CDF (quantile), p in [0,1].
    pub fn quantile(&self, p: f64) -> f64 {
        assert!(!self.values.is_empty());
        let idx = ((self.values.len() as f64 - 1.0) * p.clamp(0.0, 1.0)).round() as usize;
        self.values[idx]
    }

    /// Evenly spaced (value, probability) pairs for plotting. Empty input
    /// (an algorithm that never converged) yields an empty curve.
    pub fn curve(&self, points: usize) -> Vec<(f64, f64)> {
        if self.values.is_empty() {
            return Vec::new();
        }
        (0..points)
            .map(|i| {
                let p = i as f64 / (points - 1).max(1) as f64;
                (self.quantile(p), p)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(iter: usize, err: f64) -> IterRecord {
        IterRecord {
            iter,
            obj_err: err,
            tc_unit: (iter * 10) as f64,
            tc_energy: iter as f64 * 0.5,
            bits: (iter * 640) as f64,
            rounds: iter * 2,
            elapsed: Duration::from_millis(iter as u64),
            acv: err / 10.0,
        }
    }

    #[test]
    fn convergence_detection() {
        let mut t = Trace::new("gadmm", "test", 1e-4);
        for (k, e) in [(1, 1.0), (2, 1e-3), (3, 5e-5), (4, 1e-6)] {
            t.push(rec(k, e));
        }
        assert_eq!(t.iters_to_target(), Some(3));
        assert_eq!(t.tc_to_target(), Some(30.0));
        assert_eq!(t.bits_to_target(), Some(1920.0));
        assert!((t.final_error() - 1e-6).abs() < 1e-18);
    }

    #[test]
    fn no_convergence() {
        let mut t = Trace::new("gd", "test", 1e-4);
        t.push(rec(1, 1.0));
        assert_eq!(t.iters_to_target(), None);
        assert_eq!(t.tc_to_target(), None);
    }

    #[test]
    fn downsample_keeps_endpoints() {
        let mut t = Trace::new("x", "y", 0.0);
        for k in 0..1000 {
            t.push(rec(k, 1.0 / (k + 1) as f64));
        }
        let ds = t.downsample(50);
        assert!(ds.len() <= 50);
        assert_eq!(ds.first().unwrap().iter, 0);
        assert_eq!(ds.last().unwrap().iter, 999);
    }

    #[test]
    fn csv_roundtrip_lines() {
        let mut t = Trace::new("x", "y", 0.0);
        t.push(rec(1, 0.5));
        t.push(rec(2, 0.25));
        let mut buf = Vec::new();
        t.write_csv(&mut buf).unwrap();
        let s = String::from_utf8(buf).unwrap();
        assert_eq!(s.lines().count(), 3);
        assert!(s.starts_with("iter,"));
    }

    #[test]
    fn cdf_basics() {
        let c = Cdf::from_samples(vec![3.0, 1.0, 2.0, 4.0]);
        assert_eq!(c.at(0.5), 0.0);
        assert_eq!(c.at(2.0), 0.5);
        assert_eq!(c.at(10.0), 1.0);
        assert_eq!(c.quantile(0.0), 1.0);
        assert_eq!(c.quantile(1.0), 4.0);
        let curve = c.curve(5);
        assert_eq!(curve.len(), 5);
    }

    #[test]
    fn trace_json_summary() {
        let mut t = Trace::new("gadmm", "p", 1e-4);
        t.push(rec(1, 1e-5));
        let j = t.to_json(10);
        assert_eq!(j.path("iters_to_target").unwrap().as_usize(), Some(1));
        assert_eq!(j.path("algorithm").unwrap().as_str(), Some("gadmm"));
    }
}
