//! Deterministic fault injection on the communication path.
//!
//! The paper's D-GADMM analysis (§6) proves convergence under a
//! time-varying worker topology, and the censored follow-ups target
//! exactly the lossy wireless regimes where links drop. This module turns
//! those claims into replayable experiments: a seeded [`FaultSchedule`]
//! decides — as a *pure function of `(seed, worker, iteration)`* — whether
//! a given broadcast slot is lost, whether a worker is inside a crash or
//! partition window, and how large the modeled straggler delay of a slot
//! would be. Nothing reads a clock or an arrival order, so the same seed
//! replays the same fault pattern bit-for-bit at any execution width and
//! on both the sequential engines and the distributed coordinator (the
//! "schedule-not-clock" argument; see docs/adr/006-fault-injection.md).
//!
//! Faults compose with the existing [`LinkPolicy`] seam rather than adding
//! a new code path: [`FaultyLink`] wraps any policy and turns a dropped
//! slot into [`Msg::Skip`] *without invoking the inner policy*, so a
//! quantizer's anchor/RNG and a censor schedule advance only on slots that
//! actually reach the air — the same discipline [`Censored`] follows — and
//! the [`Meter`](super::Meter) closed forms stay exact (a dropped slot
//! charges 0 bits and 0 TC, like a censored one).
//!
//! Crash + rejoin deliberately adds no recovery machinery of its own: a
//! crashed worker is one whose broadcasts all drop for a window, and
//! recovery maps onto D-GADMM's re-chaining slot re-map (duals and links
//! travel with the physical worker), which the chaos tests pin.
//!
//! [`LinkPolicy`]: super::policy::LinkPolicy
//! [`Censored`]: super::policy::Censored

use super::policy::LinkPolicy;
use super::quantize::{Msg, MsgBuf};
use crate::util::rng::Pcg64;

/// Shared validation for the `fault=` drop-rate knob: spec strings, JSON,
/// and direct construction all funnel through this so the accepted domain
/// cannot drift between entry points. `p = 0` is legal and means "no
/// faults" (the degeneracy the property tests pin: a rate-0 faulted engine
/// is trace-identical to the unfaulted one); `p = 1` is rejected because a
/// link that never transmits cannot converge.
pub fn validate_fault_rate(p: f64) -> Result<(), String> {
    if !p.is_finite() || !(0.0..1.0).contains(&p) {
        return Err(format!("fault rate must be finite and in [0, 1), got {p}"));
    }
    Ok(())
}

/// A worker that crashes at `crash_at` and rejoins at `rejoin_at`: every
/// broadcast slot with `crash_at <= k < rejoin_at` is lost.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CrashWindow {
    pub worker: usize,
    pub crash_at: usize,
    pub rejoin_at: usize,
}

/// A network partition over `[from, until)`: the listed island is cut off
/// from the main component, so its members' broadcasts are lost until the
/// partition heals. (Links are sender-side broadcasts, so the cut is
/// modeled from the island's side; the main component keeps its cached
/// views of the islanders, exactly as under censoring.)
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PartitionWindow {
    pub island: Vec<usize>,
    pub from: usize,
    pub until: usize,
}

/// Stream salts for the per-slot draws. Distinct salts keep the drop coin
/// and the straggler delay of the same slot statistically independent.
const DROP_STREAM: u64 = 0xfa_17d0;
const DELAY_STREAM: u64 = 0xfa_17de;

/// Pareto straggler-delay shape: heavy-tailed (infinite variance for
/// `alpha <= 2`) with minimum `STRAGGLER_XM` and mean `xm·α/(α−1) = 3×`
/// the fastest slot — the classic "one slow worker dominates the round"
/// regime the chaos driver quantifies.
pub const STRAGGLER_ALPHA: f64 = 1.5;
/// Minimum (unit) slot latency of the straggler model.
pub const STRAGGLER_XM: f64 = 1.0;

/// A seeded, replayable fault plan: per-slot drop probability plus
/// explicit crash and partition windows. Every query is a pure function of
/// the schedule and its arguments — the schedule holds no mutable state,
/// so querying slots out of order (or from several threads at once) can
/// never change an answer.
#[derive(Clone, Debug)]
pub struct FaultSchedule {
    seed: u64,
    drop: f64,
    crashes: Vec<CrashWindow>,
    partitions: Vec<PartitionWindow>,
}

impl FaultSchedule {
    /// Panics on an invalid rate; parse-time entry points call
    /// [`validate_fault_rate`] first and surface the same message as an
    /// error instead (mirroring [`CensorSchedule::new`]).
    ///
    /// [`CensorSchedule::new`]: super::policy::CensorSchedule::new
    pub fn new(seed: u64, drop: f64) -> FaultSchedule {
        if let Err(e) = validate_fault_rate(drop) {
            panic!("{e}");
        }
        FaultSchedule { seed, drop, crashes: Vec::new(), partitions: Vec::new() }
    }

    /// Add a crash window: `worker` transmits nothing in
    /// `[crash_at, rejoin_at)`.
    pub fn with_crash(mut self, worker: usize, crash_at: usize, rejoin_at: usize) -> FaultSchedule {
        assert!(crash_at < rejoin_at, "crash window [{crash_at}, {rejoin_at}) is empty");
        self.crashes.push(CrashWindow { worker, crash_at, rejoin_at });
        self
    }

    /// Add a partition window: the `island` workers are cut off over
    /// `[from, until)` and heal afterwards.
    pub fn with_partition(mut self, island: &[usize], from: usize, until: usize) -> FaultSchedule {
        assert!(from < until, "partition window [{from}, {until}) is empty");
        self.partitions.push(PartitionWindow { island: island.to_vec(), from, until });
        self
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    pub fn drop_rate(&self) -> f64 {
        self.drop
    }

    /// Is `worker` inside one of its crash windows at iteration `k`?
    pub fn is_crashed(&self, worker: usize, k: usize) -> bool {
        self.crashes
            .iter()
            .any(|c| c.worker == worker && (c.crash_at..c.rejoin_at).contains(&k))
    }

    /// Is `worker` cut off by a partition at iteration `k`?
    pub fn is_partitioned(&self, worker: usize, k: usize) -> bool {
        self.partitions
            .iter()
            .any(|p| (p.from..p.until).contains(&k) && p.island.contains(&worker))
    }

    /// One independent generator per `(worker, k)` slot: the slot index is
    /// splitmix-finalized into the seed and the worker selects the stream,
    /// so each slot's draw is decorrelated from its neighbours and — the
    /// determinism contract — independent of every other query.
    fn slot_rng(&self, worker: usize, k: usize, stream: u64) -> Pcg64 {
        let mut z = self.seed ^ (k as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15);
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^= z >> 31;
        Pcg64::new(z, stream ^ ((worker as u64) << 24))
    }

    /// Does `worker`'s broadcast at iteration `k` drop? True inside any
    /// crash/partition window, else a per-slot Bernoulli(`drop`) draw.
    pub fn drops(&self, worker: usize, k: usize) -> bool {
        if self.is_crashed(worker, k) || self.is_partitioned(worker, k) {
            return true;
        }
        self.drop > 0.0 && self.slot_rng(worker, k, DROP_STREAM).coin(self.drop)
    }

    /// Modeled (not wall-clock) latency of the slot, in units of the
    /// fastest slot: Pareto(`STRAGGLER_XM`, `STRAGGLER_ALPHA`) via inverse
    /// transform `xm·u^(−1/α)`. The chaos driver sums per-round maxima to
    /// report straggler-dominated round time; nothing in the engines ever
    /// *waits* on this number, which is what keeps chaos runs replayable.
    pub fn straggler_delay(&self, worker: usize, k: usize) -> f64 {
        let u = 1.0 - self.slot_rng(worker, k, DELAY_STREAM).next_f64(); // (0, 1]
        STRAGGLER_XM * u.powf(-1.0 / STRAGGLER_ALPHA)
    }
}

/// Wrap a link policy with a fault schedule: a dropped slot becomes
/// [`Msg::Skip`] and the inner policy is *not* invoked, so its compressor
/// anchor, rounding RNG, and censor threshold state advance exactly as
/// they would on the receiving side (which saw nothing).
pub struct FaultyLink {
    inner: Box<dyn LinkPolicy>,
    schedule: FaultSchedule,
    worker: usize,
}

impl FaultyLink {
    pub fn new(inner: Box<dyn LinkPolicy>, schedule: FaultSchedule, worker: usize) -> FaultyLink {
        FaultyLink { inner, schedule, worker }
    }
}

impl LinkPolicy for FaultyLink {
    fn describe(&self) -> String {
        format!("faulty({},p={})", self.inner.describe(), self.schedule.drop_rate())
    }

    fn message_bits(&self) -> f64 {
        self.inner.message_bits()
    }

    fn transmit(&mut self, k: usize, model: &[f64]) -> Msg {
        if self.schedule.drops(self.worker, k) {
            return Msg::Skip;
        }
        self.inner.transmit(k, model)
    }

    fn transmit_into(&mut self, k: usize, model: &[f64], out: &mut MsgBuf) {
        // Same drop decision as `transmit`; the inner policy is not
        // invoked on a dropped slot, so its state advances identically.
        if self.schedule.drops(self.worker, k) {
            out.set_skip();
            return;
        }
        self.inner.transmit_into(k, model, out);
    }

    fn public_view(&self) -> &[f64] {
        self.inner.public_view()
    }
}

/// Wrap one link per worker (link `w` answers to the schedule as worker
/// `w`). Both the sequential engines and the coordinator wire factory
/// funnel through this, so the two execution paths drop the same slots.
pub fn faulty_links(
    links: Vec<Box<dyn LinkPolicy>>,
    schedule: &FaultSchedule,
) -> Vec<Box<dyn LinkPolicy>> {
    links
        .into_iter()
        .enumerate()
        .map(|(w, link)| Box::new(FaultyLink::new(link, schedule.clone(), w)) as Box<dyn LinkPolicy>)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::policy::{dense_links, quant_links, Censored, EverySlot};
    use crate::comm::quantize::{DenseCompressor, StochasticQuantizer};

    #[test]
    fn rate_domain_is_validated() {
        assert!(validate_fault_rate(0.0).is_ok(), "rate 0 disables faults");
        assert!(validate_fault_rate(0.5).is_ok());
        assert!(validate_fault_rate(1.0).is_err(), "a never-transmitting link is rejected");
        assert!(validate_fault_rate(-0.1).is_err());
        assert!(validate_fault_rate(f64::NAN).is_err());
        assert!(validate_fault_rate(f64::INFINITY).is_err());
    }

    #[test]
    fn schedule_is_a_pure_function_of_seed_worker_slot() {
        let a = FaultSchedule::new(7, 0.3);
        let b = FaultSchedule::new(7, 0.3);
        // Same answers whatever the query order — there is no hidden state.
        let forward: Vec<bool> = (0..200).map(|k| a.drops(2, k)).collect();
        let backward: Vec<bool> = (0..200).rev().map(|k| b.drops(2, k)).collect();
        assert_eq!(forward, backward.into_iter().rev().collect::<Vec<_>>());
        // And re-asking never changes an answer.
        for k in 0..200 {
            assert_eq!(a.drops(2, k), forward[k]);
            assert_eq!(a.straggler_delay(2, k).to_bits(), a.straggler_delay(2, k).to_bits());
        }
    }

    #[test]
    fn different_seeds_and_workers_decorrelate() {
        let a = FaultSchedule::new(1, 0.5);
        let b = FaultSchedule::new(2, 0.5);
        let slots = 400;
        let same_seed = (0..slots).filter(|&k| a.drops(0, k) == b.drops(0, k)).count();
        let same_worker = (0..slots).filter(|&k| a.drops(0, k) == a.drops(1, k)).count();
        // Independent fair-ish coins agree about half the time; total
        // agreement would mean the mixing collapsed.
        assert!((slots / 4..3 * slots / 4).contains(&same_seed), "seed mixing collapsed: {same_seed}");
        assert!((slots / 4..3 * slots / 4).contains(&same_worker), "worker mixing collapsed: {same_worker}");
    }

    #[test]
    fn drop_rate_is_roughly_honored() {
        let s = FaultSchedule::new(11, 0.2);
        let n = 20_000;
        let drops = (0..n).filter(|&k| s.drops(3, k)).count();
        let frac = drops as f64 / n as f64;
        assert!((frac - 0.2).abs() < 0.02, "empirical drop rate {frac}");
    }

    #[test]
    fn rate_zero_never_drops_and_windows_still_fire() {
        let s = FaultSchedule::new(5, 0.0).with_crash(1, 10, 20).with_partition(&[0, 2], 30, 35);
        for k in 0..50 {
            assert_eq!(s.drops(1, k), (10..20).contains(&k), "crash window at k={k}");
            assert_eq!(s.drops(0, k), (30..35).contains(&k), "partition window at k={k}");
            assert_eq!(s.drops(2, k), (30..35).contains(&k), "partition window at k={k}");
            assert!(!s.drops(3, k), "worker 3 is in no window and the rate is 0");
        }
        assert!(s.is_crashed(1, 10) && !s.is_crashed(1, 20), "window is half-open");
        assert!(s.is_partitioned(2, 34) && !s.is_partitioned(2, 35));
    }

    #[test]
    fn straggler_delays_are_heavy_tailed_above_xm() {
        let s = FaultSchedule::new(3, 0.0);
        let n = 5_000;
        let delays: Vec<f64> = (0..n).map(|k| s.straggler_delay(0, k)).collect();
        assert!(delays.iter().all(|&d| d >= STRAGGLER_XM), "Pareto support starts at xm");
        let mean = delays.iter().sum::<f64>() / n as f64;
        // E[Pareto(1, 1.5)] = 3; the heavy tail makes the sample mean
        // noisy, so only sanity-bound it.
        assert!(mean > 1.5 && mean < 6.0, "sample mean {mean}");
        let big = delays.iter().filter(|&&d| d > 10.0).count();
        assert!(big > 0, "no tail events in {n} draws");
    }

    #[test]
    fn dropped_slot_is_skip_and_leaves_inner_state_untouched() {
        // Mirror of the censor test: two same-seed quantized links, one
        // behind a schedule that drops slot 0 — after both transmit slot 1
        // the rounding streams must still agree, because a dropped slot
        // consumes no RNG and moves no anchor.
        let mk = || Box::new(StochasticQuantizer::for_worker(4, 4, 9, 0));
        let schedule = FaultSchedule::new(0, 0.0).with_crash(0, 0, 1);
        let mut a = FaultyLink::new(Box::new(EverySlot::new(mk())), schedule, 0);
        let mut b = EverySlot::new(mk());
        let dropped = a.transmit(0, &[0.1, 0.2, -0.1, 0.0]);
        assert!(dropped.is_skip());
        assert_eq!(dropped.payload_bits(), 0.0, "a dropped slot charges no bits");
        let x = [1.5, -2.5, 0.5, 3.0];
        let ma = a.transmit(1, &x);
        let mb = b.transmit(1, &x);
        assert!(!ma.is_skip());
        assert_eq!(a.public_view(), b.public_view(), "rounding streams diverged");
        assert_eq!(ma.payload_bits(), mb.payload_bits());
    }

    #[test]
    fn faults_compose_with_censoring() {
        // Faults wrap *outside* the censor policy: a dropped slot skips
        // the censor check entirely, so the censor threshold still decays
        // by iteration index, not by transmission count.
        let schedule = FaultSchedule::new(0, 0.0).with_crash(0, 0, 2);
        let inner = Censored::new(Box::new(DenseCompressor::new(2)), 1.0, 0.5);
        let mut link = FaultyLink::new(Box::new(inner), schedule, 0);
        assert!(link.transmit(0, &[5.0, 5.0]).is_skip(), "dropped despite a big move");
        assert!(link.transmit(1, &[5.0, 5.0]).is_skip());
        // k=2: rejoined; ‖(5,5)‖ ≈ 7.07 ≥ 0.25 ⇒ transmits.
        assert!(!link.transmit(2, &[5.0, 5.0]).is_skip());
        assert_eq!(link.public_view(), &[5.0, 5.0]);
        // k=3: threshold 0.125, tiny move ⇒ the *censor* skips now.
        assert!(link.transmit(3, &[5.0, 5.05]).is_skip());
    }

    #[test]
    fn factory_wraps_one_link_per_worker() {
        let schedule = FaultSchedule::new(1, 0.0).with_crash(1, 0, 5);
        let mut links = faulty_links(dense_links(2, 3), &schedule);
        assert_eq!(links.len(), 3);
        assert!(links[0].describe().starts_with("faulty(dense"));
        // Only worker 1 is inside the crash window.
        assert!(!links[0].transmit(0, &[1.0, 1.0]).is_skip());
        assert!(links[1].transmit(0, &[1.0, 1.0]).is_skip());
        assert!(!links[2].transmit(0, &[1.0, 1.0]).is_skip());
        // message_bits passes through the wrapper.
        let q = faulty_links(quant_links(3, 2, 8, 1), &schedule);
        assert_eq!(q[0].message_bits(), 3.0 * 8.0 + 64.0);
    }
}
