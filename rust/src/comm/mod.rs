//! Communication accounting and the compression seam.
//!
//! The paper's TC metric charges one unit (or one link-energy) per
//! *transmission slot*: a worker that broadcasts its model to its
//! neighbour set (≤2 workers on a chain, arbitrarily many on a GGADMM
//! graph) occupies one slot and pays the cost of its most expensive
//! receiving link (it transmits once at the power needed to reach the
//! farthest neighbour); a centralized uplink is a unicast slot; the
//! server downlink is a single broadcast slot bottlenecked by the weakest
//! channel. This reproduces Table 1's arithmetic exactly: GADMM pays `N`
//! per iteration, GD/ADMM pay `N + 1`, LAG pays `1 + #uploads`.
//!
//! On top of slot counting the meter tracks **payload bits**, the metric
//! the Q-GADMM follow-up optimizes. Every slot carries a payload: callers
//! either rely on the meter's default payload (a dense `d`-vector of f64,
//! set once per run by the driver) or pass the exact size through the
//! `*_bits` variants (the quantized engines do). See [`quantize`] for the
//! compressors that shrink those payloads, [`policy`] for the
//! [`LinkPolicy`] seam that additionally decides *whether* a slot is
//! occupied at all (censored slots charge nothing and are tallied in
//! [`Meter::censored`]), and [`fault`] for the seeded fault-injection
//! layer that drops slots through the same seam (a dropped slot is
//! indistinguishable from a censored one to the meter: 0 TC, 0 bits).

pub mod fault;
pub mod layers;
pub mod policy;
pub mod quantize;

pub use fault::{
    faulty_links, validate_fault_rate, CrashWindow, FaultSchedule, FaultyLink, PartitionWindow,
};
pub use layers::{
    layer_censored_dense_links, layer_dense_links, layer_quant_links, validate_layer_plan,
    LayerScheduled,
};
pub use policy::{
    censored_dense_links, censored_quant_links, dense_links, quant_links, validate_censor_params,
    CensorSchedule, Censored, EverySlot, LinkPolicy,
};
pub use quantize::{
    Compressor, Decoder, DenseCompressor, LayerChunk, Msg, MsgBuf, MsgBufKind, QuantizedMsg,
    StochasticQuantizer, FP64_BITS, RANGE_OVERHEAD_BITS,
};

use crate::topology::graph::BipartiteGraph;
use crate::topology::LinkCosts;

/// Charge one head/tail phase of a bipartite-graph schedule: every worker
/// in the group whose slot was transmitted (`sent[w] = Some(bits)`)
/// occupies one broadcast slot billed at its exact payload, with energy
/// cost the worst link of its neighbour set; censored workers
/// (`sent[w] = None`) tick [`Meter::censored`] and cost nothing. This is
/// the *single* structural-billing implementation shared by the sequential
/// [`crate::optim::GroupAdmmCore`] and the distributed coordinator's
/// leader, so the two paths cannot drift apart — part of the
/// distributed-equivalence invariant (docs/adr/003-link-policy.md; the
/// chain schedule is the degree-≤2 special case, see
/// docs/adr/004-bipartite-graph-topology.md).
pub fn charge_graph_phase(
    meter: &mut Meter<'_>,
    graph: &BipartiteGraph,
    head_phase: bool,
    sent: &[Option<f64>],
) {
    meter.begin_round();
    let group = if head_phase { graph.heads() } else { graph.tails() };
    for &w in group {
        match sent[w] {
            Some(bits) => meter.neighbor_broadcast_bits_iter(
                w,
                graph.adjacency(w).iter().map(|er| er.neighbor),
                bits,
            ),
            None => meter.censored_slot(),
        }
    }
}

/// Per-phase compute-seconds accumulator: how much solve time one run
/// spent in the head phase, the tail phase, and the dual ascent.
///
/// Filled by [`crate::optim::GroupAdmmCore::step`] (other engines leave it
/// zero) and surfaced on [`crate::metrics::Trace::phase`], this is the
/// attribution behind `gadmm bench`'s `BENCH_par.json` columns — it shows
/// *where* a pooled execution backend buys its wall-clock speedup. Pure
/// measurement: excluded from `Trace::same_path`, which compares only
/// deterministic quantities.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PhaseClock {
    /// Seconds spent solving head-group subproblems (paper eqs. 11–12).
    pub head_seconds: f64,
    /// Seconds spent solving tail-group subproblems (eqs. 13–14).
    pub tail_seconds: f64,
    /// Seconds spent on the per-edge dual ascent (eq. 15).
    pub dual_seconds: f64,
}

impl PhaseClock {
    /// Total attributed compute seconds across the three phases.
    pub fn total_seconds(&self) -> f64 {
        self.head_seconds + self.tail_seconds + self.dual_seconds
    }
}

/// Accumulating cost meter. Unit TC counts transmission slots; energy TC
/// weighs each slot by the provided [`LinkCosts`] model; `bits` sums the
/// exact payload sizes on the wire.
pub struct Meter<'a> {
    costs: &'a dyn LinkCosts,
    /// Bits charged per slot when the caller doesn't pass an explicit
    /// payload size (dense model: `64·d`). Zero until the driver sets it.
    payload_bits: f64,
    /// Cumulative transmission slots (unit-cost TC).
    pub tc_unit: f64,
    /// Cumulative energy-model TC.
    pub tc_energy: f64,
    /// Cumulative payload bits on the wire.
    pub bits: f64,
    /// Cumulative communication rounds.
    pub rounds: usize,
    /// Total transmission slots (diagnostics).
    pub transmissions: usize,
    /// Censored (skipped) slots: a worker whose turn came but whose link
    /// policy chose not to transmit. Charges no TC, no energy, no bits —
    /// the whole point of censoring — but is tallied so drivers can report
    /// how much of the schedule went unused.
    pub censored: usize,
    /// Per-worker uplink-slot counts (Fig. 6 re-weights these under many
    /// topology draws without re-running the algorithm).
    pub uplink_counts: Vec<usize>,
    /// Count of server broadcast slots.
    pub server_broadcasts: usize,
    /// Compute-seconds attribution per group-ADMM phase (zero for engines
    /// without the head/tail/dual structure). Wall-clock measurement only —
    /// never part of the deterministic trace comparison.
    pub phase: PhaseClock,
}

impl<'a> Meter<'a> {
    pub fn new(costs: &'a dyn LinkCosts) -> Meter<'a> {
        Meter {
            costs,
            payload_bits: 0.0,
            tc_unit: 0.0,
            tc_energy: 0.0,
            bits: 0.0,
            rounds: 0,
            transmissions: 0,
            censored: 0,
            uplink_counts: Vec::new(),
            server_broadcasts: 0,
            phase: PhaseClock::default(),
        }
    }

    /// A worker's slot came up but its link policy censored the
    /// transmission: nothing occupies the medium, nothing is charged.
    pub fn censored_slot(&mut self) {
        self.censored += 1;
    }

    /// Set the default payload size per slot (the drivers use the dense
    /// model size `64·d`, making every algorithm's bit accounting exact
    /// without per-engine plumbing).
    pub fn set_payload_bits(&mut self, bits: f64) {
        self.payload_bits = bits;
    }

    /// The configured default payload size per slot.
    pub fn payload_bits(&self) -> f64 {
        self.payload_bits
    }

    /// Begin a communication round (head phase, tail phase, uplink slot,
    /// downlink slot, …).
    pub fn begin_round(&mut self) {
        self.rounds += 1;
    }

    /// Worker `from` broadcasts its model to its chain neighbours in one
    /// slot; energy is the max receiving-link cost.
    pub fn neighbor_broadcast(&mut self, from: usize, neighbors: &[usize]) {
        self.neighbor_broadcast_bits(from, neighbors, self.payload_bits);
    }

    /// [`Meter::neighbor_broadcast`] with an explicit payload size.
    pub fn neighbor_broadcast_bits(&mut self, from: usize, neighbors: &[usize], bits: f64) {
        self.neighbor_broadcast_bits_iter(from, neighbors.iter().copied(), bits);
    }

    /// [`Meter::neighbor_broadcast_bits`] over any neighbour iterator —
    /// the graph billing path ([`charge_graph_phase`]) streams adjacency
    /// lists through this instead of materializing a `Vec` per slot. An
    /// empty neighbour set is free.
    pub fn neighbor_broadcast_bits_iter(
        &mut self,
        from: usize,
        neighbors: impl Iterator<Item = usize>,
        bits: f64,
    ) {
        let mut any = false;
        let mut worst = 0.0f64;
        for to in neighbors {
            any = true;
            worst = worst.max(self.costs.link(from, to));
        }
        if !any {
            return;
        }
        self.transmissions += 1;
        self.tc_unit += 1.0;
        self.bits += bits;
        self.tc_energy += worst;
    }

    /// Worker `from` unicasts to worker `to` (one slot).
    pub fn unicast(&mut self, from: usize, to: usize) {
        self.unicast_bits(from, to, self.payload_bits);
    }

    /// [`Meter::unicast`] with an explicit payload size.
    pub fn unicast_bits(&mut self, from: usize, to: usize, bits: f64) {
        self.transmissions += 1;
        self.tc_unit += 1.0;
        self.bits += bits;
        self.tc_energy += self.costs.link(from, to);
    }

    /// Worker `n` unicasts to the central controller.
    pub fn uplink(&mut self, n: usize) {
        self.uplink_bits(n, self.payload_bits);
    }

    /// [`Meter::uplink`] with an explicit payload size.
    pub fn uplink_bits(&mut self, n: usize, bits: f64) {
        self.transmissions += 1;
        self.tc_unit += 1.0;
        self.bits += bits;
        self.tc_energy += self.costs.uplink(n);
        if self.uplink_counts.len() <= n {
            self.uplink_counts.resize(n + 1, 0);
        }
        self.uplink_counts[n] += 1;
    }

    /// Central controller broadcasts to all workers (one slot, weakest
    /// channel is the bottleneck).
    pub fn server_broadcast(&mut self) {
        self.server_broadcast_bits(self.payload_bits);
    }

    /// [`Meter::server_broadcast`] with an explicit payload size.
    pub fn server_broadcast_bits(&mut self, bits: f64) {
        self.transmissions += 1;
        self.tc_unit += 1.0;
        self.bits += bits;
        self.tc_energy += self.costs.server_broadcast();
        self.server_broadcasts += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{EnergyCostModel, Placement, UnitCosts};
    use crate::util::rng::Pcg64;

    #[test]
    fn unit_accounting_matches_paper_arithmetic() {
        let costs = UnitCosts;
        let mut m = Meter::new(&costs);
        // One GADMM iteration on N=14: every worker transmits once.
        m.begin_round();
        for w in (0..14usize).step_by(2) {
            let neigh: Vec<usize> = [w.checked_sub(1), Some(w + 1).filter(|&x| x < 14)]
                .into_iter()
                .flatten()
                .collect();
            m.neighbor_broadcast(w, &neigh);
        }
        m.begin_round();
        for w in (1..14).step_by(2) {
            let neigh: Vec<usize> = [Some(w - 1), Some(w + 1).filter(|&x| x < 14)]
                .into_iter()
                .flatten()
                .collect();
            m.neighbor_broadcast(w, &neigh);
        }
        assert_eq!(m.tc_unit, 14.0); // N per iteration — Table 1: 78·14 = 1092
        assert_eq!(m.rounds, 2);

        // One GD iteration: N uplinks + broadcast = N + 1.
        let mut g = Meter::new(&costs);
        g.begin_round();
        for w in 0..14 {
            g.uplink(w);
        }
        g.begin_round();
        g.server_broadcast();
        assert_eq!(g.tc_unit, 15.0); // Table 1: 524·15 = 7860
    }

    #[test]
    fn energy_uses_max_link_for_broadcast() {
        let p = Placement {
            side: 10.0,
            positions: vec![(0.0, 0.0), (1.0, 0.0), (5.0, 0.0)],
        };
        let costs = EnergyCostModel::new(&p, 0);
        let mut m = Meter::new(&costs);
        m.neighbor_broadcast(0, &[1, 2]);
        let expect = crate::topology::tx_energy(5.0);
        assert!((m.tc_energy - expect).abs() < 1e-12);
        assert_eq!(m.tc_unit, 1.0);
    }

    #[test]
    fn empty_neighbor_list_is_free() {
        let costs = UnitCosts;
        let mut m = Meter::new(&costs);
        m.neighbor_broadcast(0, &[]);
        assert_eq!(m.tc_unit, 0.0);
        assert_eq!(m.transmissions, 0);
    }

    #[test]
    fn payload_bits_accounting() {
        let costs = UnitCosts;
        let mut m = Meter::new(&costs);
        // Default payload is zero until a driver sets it.
        m.neighbor_broadcast(0, &[1]);
        assert_eq!(m.bits, 0.0);
        m.set_payload_bits(64.0 * 8.0);
        m.neighbor_broadcast(1, &[0, 2]);
        m.uplink(3);
        m.server_broadcast();
        assert_eq!(m.bits, 3.0 * 512.0);
        // Explicit payloads override the default per slot.
        m.unicast_bits(0, 1, 100.0);
        assert_eq!(m.bits, 3.0 * 512.0 + 100.0);
        // An empty neighbour list is free in bits too.
        m.neighbor_broadcast_bits(0, &[], 999.0);
        assert_eq!(m.bits, 3.0 * 512.0 + 100.0);
        assert_eq!(m.payload_bits(), 512.0);
    }

    #[test]
    fn censored_slot_charges_nothing() {
        let costs = UnitCosts;
        let mut m = Meter::new(&costs);
        m.set_payload_bits(512.0);
        m.neighbor_broadcast(0, &[1]);
        m.censored_slot();
        m.censored_slot();
        assert_eq!(m.censored, 2);
        assert_eq!(m.tc_unit, 1.0, "censored slots must not count as TC");
        assert_eq!(m.tc_energy, 1.0);
        assert_eq!(m.bits, 512.0, "censored slots must charge 0 bits");
        assert_eq!(m.transmissions, 1);
    }

    #[test]
    fn mixed_dense_quantized_skipped_accounting_closed_form() {
        // Interleaved dense / quantized / censored slots sum exactly.
        let costs = UnitCosts;
        let mut m = Meter::new(&costs);
        let d = 7usize;
        let b = 5u32;
        let dense = 64.0 * d as f64;
        let quant = d as f64 * b as f64 + 64.0;
        let (mut nd, mut nq, mut ns) = (0usize, 0usize, 0usize);
        for i in 0..30 {
            match i % 3 {
                0 => {
                    m.neighbor_broadcast_bits(0, &[1], dense);
                    nd += 1;
                }
                1 => {
                    m.neighbor_broadcast_bits(1, &[0, 2], quant);
                    nq += 1;
                }
                _ => {
                    m.censored_slot();
                    ns += 1;
                }
            }
        }
        assert_eq!(m.bits, nd as f64 * dense + nq as f64 * quant);
        assert_eq!(m.tc_unit, (nd + nq) as f64);
        assert_eq!(m.transmissions, nd + nq);
        assert_eq!(m.censored, ns);
    }

    #[test]
    fn randomized_meter_is_additive() {
        let mut rng = Pcg64::seeded(9);
        let p = Placement::random(6, 10.0, &mut rng);
        let costs = EnergyCostModel::new(&p, p.central_worker());
        let mut m = Meter::new(&costs);
        let mut expect = 0.0;
        for _ in 0..50 {
            let a = rng.range(0, 6);
            let b = (a + 1 + rng.range(0, 5)) % 6;
            m.unicast(a, b);
            expect += costs.link(a, b);
        }
        assert!((m.tc_energy - expect).abs() < 1e-9);
        assert_eq!(m.tc_unit, 50.0);
    }
}
