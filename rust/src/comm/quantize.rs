//! Pluggable model compression for the communication path.
//!
//! The paper's follow-ups (Q-GADMM, CQ-GGADMM) win their communication
//! budget not by sending fewer *messages* but by sending fewer *bits per
//! message*. This module provides the seam: a [`Compressor`] turns a model
//! vector into a wire [`Msg`] with an exact bit size, and a [`Decoder`]
//! reconstructs the receivers' view. Everything on the wire is accounted
//! bit-exactly by [`crate::comm::Meter`].
//!
//! Two compressors ship today:
//!
//! * [`DenseCompressor`] — the identity: `d` f64 coordinates, `64·d` bits.
//! * [`StochasticQuantizer`] — the Q-GADMM scheme (Elgabli et al., 2019):
//!   stochastic uniform quantization of the **difference** from the
//!   previously transmitted model. With `b` bits per coordinate, the `2^b`
//!   levels span `[prev_i − R, prev_i + R]` where the scalar range
//!   `R = max_i |θ_i − prev_i|` is transmitted alongside the levels. As the
//!   iterates converge the successive differences — and therefore `R` —
//!   contract toward zero, so a *fixed* `b` buys ever finer absolute
//!   precision and the algorithm converges to the exact optimum. Stochastic
//!   rounding keeps the reconstruction unbiased:
//!   `E[decode(encode(x))] = x`.
//!
//! Senders and receivers both reconstruct the transmitted model with the
//! same f64 arithmetic from `(prev, R, levels)`, so the "public" view of a
//! worker's model is bit-identical everywhere — the property the Q-GADMM
//! dual updates rely on. *Whether* to occupy a slot at all is one level up:
//! a [`crate::comm::LinkPolicy`] decides per slot (censoring emits
//! [`Msg::Skip`] with zero payload bits) and delegates the encoding to a
//! [`Compressor`] (see docs/adr/003-link-policy.md).

use crate::util::rng::Pcg64;

/// Bits of one dense f64 coordinate.
pub const FP64_BITS: f64 = 64.0;

/// Per-message overhead of a quantized payload: the f64 range scalar.
pub const RANGE_OVERHEAD_BITS: f64 = 64.0;

/// RNG stream tag for per-worker quantizer generators (keeps sequential
/// engines and coordinator workers bit-identical for the same seed).
const QUANT_STREAM: u64 = 0x71_6741; // "qgA"

/// One quantized broadcast: the shared range and `b`-bit level indices.
#[derive(Clone, Debug, PartialEq)]
pub struct QuantizedMsg {
    /// Half-width of the quantization interval around the previous model.
    pub range: f64,
    /// Bits per coordinate (levels are in `[0, 2^b − 1]`).
    pub bits_per_coord: u32,
    /// Level index per coordinate.
    pub levels: Vec<u32>,
}

impl QuantizedMsg {
    /// Exact wire size: `d·b` level bits plus the range scalar.
    pub fn payload_bits(&self) -> f64 {
        self.levels.len() as f64 * self.bits_per_coord as f64 + RANGE_OVERHEAD_BITS
    }

    /// Reconstruct the transmitted model given the receiver's mirror of the
    /// previously transmitted model. Pure function of the message and
    /// `prev`, so sender and receivers agree bit-for-bit.
    pub fn decode(&self, prev: &[f64]) -> Vec<f64> {
        assert_eq!(prev.len(), self.levels.len());
        if self.range == 0.0 {
            return prev.to_vec();
        }
        let max_level = ((1u64 << self.bits_per_coord) - 1) as f64;
        let step = 2.0 * self.range / max_level;
        prev.iter()
            .zip(&self.levels)
            .map(|(&p, &idx)| (p - self.range) + idx as f64 * step)
            .collect()
    }
}

/// A wire message on the model-exchange path.
#[derive(Clone, Debug, PartialEq)]
pub enum Msg {
    /// Uncompressed model (64 bits per coordinate).
    Dense(Vec<f64>),
    /// Q-GADMM quantized difference from the previously transmitted model.
    Quantized(QuantizedMsg),
    /// Censored slot: the sender's model change fell under its censoring
    /// threshold, so nothing occupies the medium. Receivers keep their
    /// cached view of the sender (C-GADMM / CQ-GADMM semantics). In the
    /// threaded coordinator a `Skip` still travels the channel — it models
    /// the receiver's *timeout*, not a transmission — and costs 0 bits.
    Skip,
}

impl Msg {
    /// Exact payload size on the wire, in bits.
    pub fn payload_bits(&self) -> f64 {
        match self {
            Msg::Dense(v) => v.len() as f64 * FP64_BITS,
            Msg::Quantized(q) => q.payload_bits(),
            Msg::Skip => 0.0,
        }
    }

    /// Whether this message is a censored (skipped) slot.
    pub fn is_skip(&self) -> bool {
        matches!(self, Msg::Skip)
    }
}

/// Sender-side compression state for one worker's broadcasts.
///
/// Implementations may carry state across calls (the quantizer tracks the
/// previously transmitted model); [`Compressor::compress`] advances that
/// state as if the message were delivered, and [`Compressor::public_view`]
/// is the model every receiver currently holds for this sender.
pub trait Compressor: Send {
    /// Short label for engine names, e.g. `"dense"` or `"q8"`.
    fn describe(&self) -> String;

    /// Exact wire size of the next message this compressor will emit.
    /// Both shipped compressors are constant-size; the structural billing
    /// in the coordinator's leader relies on that.
    fn message_bits(&self) -> f64;

    /// Encode `model` for one broadcast and advance the sender state.
    fn compress(&mut self, model: &[f64]) -> Msg;

    /// The receivers' current view of this sender's model (what the last
    /// [`Compressor::compress`] reconstructed to).
    fn public_view(&self) -> &[f64];
}

/// Identity compression: full-precision broadcast, `64·d` bits.
pub struct DenseCompressor {
    last: Vec<f64>,
}

impl DenseCompressor {
    pub fn new(dim: usize) -> DenseCompressor {
        DenseCompressor {
            last: vec![0.0; dim],
        }
    }
}

impl Compressor for DenseCompressor {
    fn describe(&self) -> String {
        "dense".to_string()
    }

    fn message_bits(&self) -> f64 {
        self.last.len() as f64 * FP64_BITS
    }

    fn compress(&mut self, model: &[f64]) -> Msg {
        self.last.copy_from_slice(model);
        Msg::Dense(model.to_vec())
    }

    fn public_view(&self) -> &[f64] {
        &self.last
    }
}

/// The Q-GADMM stochastic uniform quantizer (sender side).
pub struct StochasticQuantizer {
    /// Previously transmitted (reconstructed) model — the quantization
    /// anchor shared with every receiver.
    prev: Vec<f64>,
    bits: u32,
    rng: Pcg64,
}

impl StochasticQuantizer {
    /// `bits` per coordinate in `[1, 32]`; `seed` makes the stochastic
    /// rounding reproducible.
    pub fn new(dim: usize, bits: u32, seed: u64) -> StochasticQuantizer {
        assert!((1..=32).contains(&bits), "quantizer bits must be in 1..=32");
        StochasticQuantizer {
            prev: vec![0.0; dim],
            bits,
            rng: Pcg64::new(seed, QUANT_STREAM),
        }
    }

    /// The per-worker constructor used by both the sequential engine and
    /// the distributed coordinator — same (seed, worker) ⇒ same rounding
    /// sequence, which keeps the two execution paths bit-identical.
    pub fn for_worker(dim: usize, bits: u32, seed: u64, worker: usize) -> StochasticQuantizer {
        let tag = ((worker as u64) << 32) | worker as u64;
        StochasticQuantizer::new(dim, bits, seed.wrapping_add(tag))
    }

    pub fn bits(&self) -> u32 {
        self.bits
    }

    /// Quantize `model` against the previously transmitted model and
    /// advance the anchor to the reconstruction.
    pub fn encode(&mut self, model: &[f64]) -> QuantizedMsg {
        assert_eq!(model.len(), self.prev.len());
        let range = model
            .iter()
            .zip(&self.prev)
            .map(|(&x, &p)| (x - p).abs())
            .fold(0.0f64, f64::max);
        // `f64::max` ignores NaN deltas, so check finiteness explicitly:
        // a diverged (NaN/inf) iterate must freeze the anchor rather than
        // decode to a fabricated finite value.
        let finite = model.iter().all(|v| v.is_finite());
        if range == 0.0 || !range.is_finite() || !finite {
            // Nothing moved (or the iterate diverged to non-finite values):
            // transmit the degenerate range; receivers keep `prev`.
            return QuantizedMsg {
                range: 0.0,
                bits_per_coord: self.bits,
                levels: vec![0; model.len()],
            };
        }
        let max_level = ((1u64 << self.bits) - 1) as f64;
        let step = 2.0 * range / max_level;
        let levels: Vec<u32> = model
            .iter()
            .zip(&self.prev)
            .map(|(&x, &p)| {
                let pos = (x - (p - range)) / step;
                let lo = pos.floor();
                let frac = pos - lo;
                // Stochastic rounding: up with probability `frac`, so the
                // reconstruction is unbiased.
                let idx = lo + if self.rng.next_f64() < frac { 1.0 } else { 0.0 };
                idx.clamp(0.0, max_level) as u32
            })
            .collect();
        let msg = QuantizedMsg {
            range,
            bits_per_coord: self.bits,
            levels,
        };
        self.prev = msg.decode(&self.prev);
        msg
    }
}

impl Compressor for StochasticQuantizer {
    fn describe(&self) -> String {
        format!("q{}", self.bits)
    }

    /// Wire size of every message this quantizer emits (`d·b + 64`).
    fn message_bits(&self) -> f64 {
        self.prev.len() as f64 * self.bits as f64 + RANGE_OVERHEAD_BITS
    }

    fn compress(&mut self, model: &[f64]) -> Msg {
        Msg::Quantized(self.encode(model))
    }

    fn public_view(&self) -> &[f64] {
        &self.prev
    }
}

/// Receiver-side state: mirrors one sender's previously transmitted model
/// and applies incoming messages to it.
pub struct Decoder {
    prev: Vec<f64>,
}

impl Decoder {
    pub fn new(dim: usize) -> Decoder {
        Decoder {
            prev: vec![0.0; dim],
        }
    }

    /// Apply one message and return the sender's current public model.
    /// A censored slot ([`Msg::Skip`]) leaves the cached view untouched —
    /// exactly what a receiver that heard nothing would do.
    pub fn apply(&mut self, msg: &Msg) -> &[f64] {
        match msg {
            Msg::Dense(v) => {
                self.prev.copy_from_slice(v);
            }
            Msg::Quantized(q) => {
                self.prev = q.decode(&self.prev);
            }
            Msg::Skip => {}
        }
        &self.prev
    }

    /// The current view without applying anything.
    pub fn view(&self) -> &[f64] {
        &self.prev
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_roundtrip_is_exact() {
        let mut c = DenseCompressor::new(3);
        let x = vec![1.0, -2.5, 0.25];
        let msg = c.compress(&x);
        assert_eq!(msg.payload_bits(), 3.0 * FP64_BITS);
        assert_eq!(c.message_bits(), 3.0 * FP64_BITS);
        let mut d = Decoder::new(3);
        assert_eq!(d.apply(&msg), x.as_slice());
        assert_eq!(c.public_view(), x.as_slice());
        assert_eq!(c.describe(), "dense");
    }

    #[test]
    fn non_finite_model_freezes_anchor() {
        let mut q = StochasticQuantizer::new(3, 8, 1);
        let _ = q.encode(&[1.0, 2.0, 3.0]);
        let anchor = q.public_view().to_vec();
        let msg = q.encode(&[f64::NAN, 2.0, 3.0]);
        assert_eq!(msg.range, 0.0, "NaN coordinate must freeze the anchor");
        assert_eq!(q.public_view(), anchor.as_slice());
        let msg = q.encode(&[f64::INFINITY, 0.0, 0.0]);
        assert_eq!(msg.range, 0.0, "inf coordinate must freeze the anchor");
        assert_eq!(q.public_view(), anchor.as_slice());
    }

    #[test]
    fn quantized_roundtrip_error_bounded_by_step() {
        let mut rng = Pcg64::seeded(5);
        for bits in [2u32, 4, 8, 12] {
            let mut q = StochasticQuantizer::new(16, bits, 9);
            let x = rng.normal_vec(16);
            let msg = q.encode(&x);
            let rec = q.public_view();
            let step = 2.0 * msg.range / ((1u64 << bits) - 1) as f64;
            for (xi, ri) in x.iter().zip(rec) {
                assert!(
                    (xi - ri).abs() <= step + 1e-12,
                    "b={bits}: |{xi} − {ri}| > step {step}"
                );
            }
            assert_eq!(msg.payload_bits(), 16.0 * bits as f64 + RANGE_OVERHEAD_BITS);
        }
    }

    #[test]
    fn sender_and_receiver_views_agree_bitwise() {
        let mut q = StochasticQuantizer::for_worker(8, 6, 3, 2);
        let mut d = Decoder::new(8);
        let mut rng = Pcg64::seeded(11);
        for _ in 0..20 {
            let x = rng.normal_vec(8);
            let msg = q.compress(&x);
            let seen = d.apply(&msg).to_vec();
            assert_eq!(seen, q.public_view(), "sender/receiver divergence");
        }
    }

    #[test]
    fn zero_delta_sends_degenerate_range() {
        let mut q = StochasticQuantizer::new(4, 8, 1);
        let x = vec![0.5, -0.5, 1.0, 0.0];
        let _ = q.encode(&x);
        let anchored = q.public_view().to_vec();
        let msg = q.encode(&anchored);
        assert_eq!(msg.range, 0.0);
        assert_eq!(q.public_view(), anchored.as_slice());
        let mut d = Decoder::new(4);
        // Receiver replays both messages and lands on the same anchor.
        d.apply(&Msg::Quantized(QuantizedMsg {
            range: 0.0,
            bits_per_coord: 8,
            levels: vec![0; 4],
        }));
        assert_eq!(d.view(), vec![0.0; 4].as_slice());
    }

    #[test]
    fn fixed_seed_is_reproducible() {
        let mut a = StochasticQuantizer::new(10, 4, 77);
        let mut b = StochasticQuantizer::new(10, 4, 77);
        let mut rng = Pcg64::seeded(1);
        for _ in 0..5 {
            let x = rng.normal_vec(10);
            assert_eq!(a.encode(&x), b.encode(&x));
        }
    }

    #[test]
    fn describe_labels_bits() {
        assert_eq!(StochasticQuantizer::new(2, 8, 0).describe(), "q8");
    }
}
