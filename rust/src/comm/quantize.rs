//! Pluggable model compression for the communication path.
//!
//! The paper's follow-ups (Q-GADMM, CQ-GGADMM) win their communication
//! budget not by sending fewer *messages* but by sending fewer *bits per
//! message*. This module provides the seam: a [`Compressor`] turns a model
//! vector into a wire [`Msg`] with an exact bit size, and a [`Decoder`]
//! reconstructs the receivers' view. Everything on the wire is accounted
//! bit-exactly by [`crate::comm::Meter`].
//!
//! Two compressors ship today:
//!
//! * [`DenseCompressor`] — the identity: `d` f64 coordinates, `64·d` bits.
//! * [`StochasticQuantizer`] — the Q-GADMM scheme (Elgabli et al., 2019):
//!   stochastic uniform quantization of the **difference** from the
//!   previously transmitted model. With `b` bits per coordinate, the `2^b`
//!   levels span `[prev_i − R, prev_i + R]` where the scalar range
//!   `R = max_i |θ_i − prev_i|` is transmitted alongside the levels. As the
//!   iterates converge the successive differences — and therefore `R` —
//!   contract toward zero, so a *fixed* `b` buys ever finer absolute
//!   precision and the algorithm converges to the exact optimum. Stochastic
//!   rounding keeps the reconstruction unbiased:
//!   `E[decode(encode(x))] = x`.
//!
//! Senders and receivers both reconstruct the transmitted model with the
//! same f64 arithmetic from `(prev, R, levels)`, so the "public" view of a
//! worker's model is bit-identical everywhere — the property the Q-GADMM
//! dual updates rely on. *Whether* to occupy a slot at all is one level up:
//! a [`crate::comm::LinkPolicy`] decides per slot (censoring emits
//! [`Msg::Skip`] with zero payload bits) and delegates the encoding to a
//! [`Compressor`] (see docs/adr/003-link-policy.md).

use crate::util::rng::Pcg64;

/// Bits of one dense f64 coordinate.
pub const FP64_BITS: f64 = 64.0;

/// Per-message overhead of a quantized payload: the f64 range scalar.
pub const RANGE_OVERHEAD_BITS: f64 = 64.0;

/// RNG stream tag for per-worker quantizer generators (keeps sequential
/// engines and coordinator workers bit-identical for the same seed).
const QUANT_STREAM: u64 = 0x71_6741; // "qgA"

/// One quantized broadcast: the shared range and `b`-bit level indices.
#[derive(Clone, Debug, PartialEq)]
pub struct QuantizedMsg {
    /// Half-width of the quantization interval around the previous model.
    pub range: f64,
    /// Bits per coordinate (levels are in `[0, 2^b − 1]`).
    pub bits_per_coord: u32,
    /// Level index per coordinate.
    pub levels: Vec<u32>,
}

impl QuantizedMsg {
    /// Exact wire size: `d·b` level bits plus the range scalar.
    pub fn payload_bits(&self) -> f64 {
        self.levels.len() as f64 * self.bits_per_coord as f64 + RANGE_OVERHEAD_BITS
    }

    /// Reconstruct the transmitted model given the receiver's mirror of the
    /// previously transmitted model. Pure function of the message and
    /// `prev`, so sender and receivers agree bit-for-bit.
    pub fn decode(&self, prev: &[f64]) -> Vec<f64> {
        let mut out = prev.to_vec();
        self.decode_into(&mut out);
        out
    }

    /// Allocation-free decode: update the receiver's mirror in place.
    /// Per-coordinate arithmetic is identical to [`QuantizedMsg::decode`]
    /// (each output reads only its own `prev` coordinate, so in-place is
    /// safe); a degenerate `range == 0` message leaves `prev` untouched.
    pub fn decode_into(&self, prev: &mut [f64]) {
        assert_eq!(prev.len(), self.levels.len());
        if self.range == 0.0 {
            return;
        }
        let max_level = ((1u64 << self.bits_per_coord) - 1) as f64;
        let step = 2.0 * self.range / max_level;
        for (p, &idx) in prev.iter_mut().zip(&self.levels) {
            *p = (*p - self.range) + idx as f64 * step;
        }
    }
}

/// One layer's slice of a layered broadcast ([`Msg::Layers`]): an inner
/// payload applied at a flat `offset` into the receiver's mirror. The
/// inner message is [`Msg::Dense`] or [`Msg::Quantized`] — never another
/// `Layers`, and never `Skip` (a stale or censored layer is simply absent
/// from the chunk list).
#[derive(Clone, Debug, PartialEq)]
pub struct LayerChunk {
    /// Flat offset of this layer in the model vector.
    pub offset: usize,
    /// The layer's encoded payload.
    pub msg: Msg,
}

/// A wire message on the model-exchange path.
#[derive(Clone, Debug, PartialEq)]
pub enum Msg {
    /// Uncompressed model (64 bits per coordinate).
    Dense(Vec<f64>),
    /// Q-GADMM quantized difference from the previously transmitted model.
    Quantized(QuantizedMsg),
    /// L-FGADMM layered broadcast: only the scheduled (and uncensored)
    /// layers travel, each as an independently encoded chunk at its flat
    /// offset. Receivers keep their cached view of every absent layer —
    /// per-layer `Skip` semantics. Payload bits are the sum of the chunks;
    /// the untransmitted remainder of the model costs nothing.
    Layers(Vec<LayerChunk>),
    /// Censored slot: the sender's model change fell under its censoring
    /// threshold, so nothing occupies the medium. Receivers keep their
    /// cached view of the sender (C-GADMM / CQ-GADMM semantics). In the
    /// threaded coordinator a `Skip` still travels the channel — it models
    /// the receiver's *timeout*, not a transmission — and costs 0 bits.
    Skip,
}

impl Msg {
    /// Exact payload size on the wire, in bits.
    pub fn payload_bits(&self) -> f64 {
        match self {
            Msg::Dense(v) => v.len() as f64 * FP64_BITS,
            Msg::Quantized(q) => q.payload_bits(),
            Msg::Layers(chunks) => chunks.iter().map(|c| c.msg.payload_bits()).sum(),
            Msg::Skip => 0.0,
        }
    }

    /// Whether this message is a censored (skipped) slot.
    pub fn is_skip(&self) -> bool {
        matches!(self, Msg::Skip)
    }
}

/// What a [`MsgBuf`] currently holds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MsgBufKind {
    Dense,
    Quantized,
    Layers,
    Skip,
}

/// A reusable, caller-owned encoding buffer: the allocation-free
/// counterpart of [`Msg`] for the in-process hot path.
///
/// [`Msg`] owns its payload (`Vec<f64>` / `Vec<u32>`), which costs one
/// heap allocation per transmit — fine on the wire (net/frame.rs keeps
/// speaking `Msg`), wasteful in the sequential engines where the message
/// is consumed immediately. A `MsgBuf` holds both payload shapes at their
/// steady-state capacity and is rewritten in place by
/// [`Compressor::encode_into`] / `LinkPolicy::transmit_into`. Bit
/// accounting matches [`Msg::payload_bits`] case for case.
#[derive(Clone, Debug)]
pub struct MsgBuf {
    kind: MsgBufKind,
    dense: Vec<f64>,
    qrange: f64,
    qbits: u32,
    levels: Vec<u32>,
    /// Reusable per-layer chunk buffers for [`MsgBufKind::Layers`]:
    /// `(flat offset, inner buffer)`. Only the first `layers_active`
    /// entries are live; the rest keep their capacity for reuse. Grows
    /// only while a new high-water mark of simultaneous layers is seen —
    /// iteration 0 transmits every layer, so steady state never grows it.
    layers: Vec<(usize, MsgBuf)>,
    layers_active: usize,
}

impl MsgBuf {
    /// An empty (skip) buffer with both payloads preallocated for
    /// dimension `dim`, so steady-state rewrites never grow it.
    pub fn new(dim: usize) -> MsgBuf {
        MsgBuf {
            kind: MsgBufKind::Skip,
            dense: vec![0.0; dim],
            qrange: 0.0,
            qbits: 0,
            levels: vec![0; dim],
            layers: Vec::new(),
            layers_active: 0,
        }
    }

    pub fn kind(&self) -> MsgBufKind {
        self.kind
    }

    /// Whether the buffer holds a censored/dropped slot.
    pub fn is_skip(&self) -> bool {
        self.kind == MsgBufKind::Skip
    }

    /// Exact payload size in bits — the same accounting as
    /// [`Msg::payload_bits`] for the equivalent message.
    pub fn payload_bits(&self) -> f64 {
        match self.kind {
            MsgBufKind::Dense => self.dense.len() as f64 * FP64_BITS,
            MsgBufKind::Quantized => {
                self.levels.len() as f64 * self.qbits as f64 + RANGE_OVERHEAD_BITS
            }
            MsgBufKind::Layers => self.layers[..self.layers_active]
                .iter()
                .map(|(_, b)| b.payload_bits())
                .sum(),
            MsgBufKind::Skip => 0.0,
        }
    }

    /// Mark the buffer as a censored/dropped slot (payload left in place,
    /// never read).
    pub fn set_skip(&mut self) {
        self.kind = MsgBufKind::Skip;
    }

    /// Rewrite as a dense payload copied from `model`.
    pub fn set_dense(&mut self, model: &[f64]) {
        self.kind = MsgBufKind::Dense;
        self.dense.resize(model.len(), 0.0);
        self.dense.copy_from_slice(model);
    }

    /// Rewrite as a quantized payload: sets the header and sizes the level
    /// buffer (zero-filled) for the encoder to fill in place.
    pub fn begin_quantized(&mut self, range: f64, bits: u32, dim: usize) {
        self.kind = MsgBufKind::Quantized;
        self.qrange = range;
        self.qbits = bits;
        self.levels.clear();
        self.levels.resize(dim, 0);
    }

    /// Mutable access to the quantized level indices (valid after
    /// [`MsgBuf::begin_quantized`]).
    pub fn levels_mut(&mut self) -> &mut [u32] {
        &mut self.levels
    }

    /// Rewrite as a layered payload with no chunks yet; fill with
    /// [`MsgBuf::push_layer`]. Existing chunk buffers keep their capacity.
    pub fn begin_layers(&mut self) {
        self.kind = MsgBufKind::Layers;
        self.layers_active = 0;
    }

    /// Append one layer chunk at flat `offset` and return its inner buffer
    /// for the encoder to fill. Reuses a retired chunk buffer when one is
    /// available; allocates only at a new high-water mark of simultaneous
    /// layers (iteration 0 of a layered schedule, when every layer is due).
    pub fn push_layer(&mut self, offset: usize) -> &mut MsgBuf {
        debug_assert_eq!(self.kind, MsgBufKind::Layers);
        if self.layers_active == self.layers.len() {
            self.layers.push((offset, MsgBuf::new(0)));
        }
        let slot = &mut self.layers[self.layers_active];
        slot.0 = offset;
        self.layers_active += 1;
        &mut slot.1
    }

    /// Discard the most recently pushed layer chunk (the inner policy
    /// censored it); its buffer is retained for reuse.
    pub fn retract_layer(&mut self) {
        debug_assert_eq!(self.kind, MsgBufKind::Layers);
        debug_assert!(self.layers_active > 0);
        self.layers_active -= 1;
    }

    /// Number of live layer chunks (valid after [`MsgBuf::begin_layers`]).
    pub fn num_layers(&self) -> usize {
        debug_assert_eq!(self.kind, MsgBufKind::Layers);
        self.layers_active
    }

    /// Copy an owned [`Msg`] into the buffer — the default-impl bridge for
    /// third-party compressors that only implement the allocating path.
    pub fn set_msg(&mut self, msg: &Msg) {
        match msg {
            Msg::Dense(v) => self.set_dense(v),
            Msg::Quantized(q) => {
                self.begin_quantized(q.range, q.bits_per_coord, q.levels.len());
                self.levels.copy_from_slice(&q.levels);
            }
            Msg::Layers(chunks) => {
                self.begin_layers();
                for c in chunks {
                    self.push_layer(c.offset).set_msg(&c.msg);
                }
            }
            Msg::Skip => self.set_skip(),
        }
    }

    /// Materialize the equivalent owned [`Msg`] (allocates — wire path and
    /// tests only, never the steady-state loop).
    pub fn to_msg(&self) -> Msg {
        match self.kind {
            MsgBufKind::Dense => Msg::Dense(self.dense.clone()),
            MsgBufKind::Quantized => Msg::Quantized(QuantizedMsg {
                range: self.qrange,
                bits_per_coord: self.qbits,
                levels: self.levels.clone(),
            }),
            MsgBufKind::Layers => Msg::Layers(
                self.layers[..self.layers_active]
                    .iter()
                    .map(|(off, b)| LayerChunk { offset: *off, msg: b.to_msg() })
                    .collect(),
            ),
            MsgBufKind::Skip => Msg::Skip,
        }
    }
}

/// Sender-side compression state for one worker's broadcasts.
///
/// Implementations may carry state across calls (the quantizer tracks the
/// previously transmitted model); [`Compressor::compress`] advances that
/// state as if the message were delivered, and [`Compressor::public_view`]
/// is the model every receiver currently holds for this sender.
pub trait Compressor: Send {
    /// Short label for engine names, e.g. `"dense"` or `"q8"`.
    fn describe(&self) -> String;

    /// Exact wire size of the next message this compressor will emit.
    /// Both shipped compressors are constant-size; the structural billing
    /// in the coordinator's leader relies on that.
    fn message_bits(&self) -> f64;

    /// Encode `model` for one broadcast and advance the sender state.
    fn compress(&mut self, model: &[f64]) -> Msg;

    /// Allocation-free encode: rewrite the caller's reusable [`MsgBuf`] in
    /// place instead of allocating a [`Msg`]. State advance, payload bits,
    /// and (for stateful compressors) RNG consumption are identical to
    /// [`Compressor::compress`] — the shipped compressors route both
    /// methods through one arithmetic path. The default bridges through
    /// the allocating path so third-party compressors keep working.
    fn encode_into(&mut self, model: &[f64], out: &mut MsgBuf) {
        out.set_msg(&self.compress(model));
    }

    /// The receivers' current view of this sender's model (what the last
    /// [`Compressor::compress`] reconstructed to).
    fn public_view(&self) -> &[f64];
}

/// Identity compression: full-precision broadcast, `64·d` bits.
pub struct DenseCompressor {
    last: Vec<f64>,
}

impl DenseCompressor {
    pub fn new(dim: usize) -> DenseCompressor {
        DenseCompressor {
            last: vec![0.0; dim],
        }
    }
}

impl Compressor for DenseCompressor {
    fn describe(&self) -> String {
        "dense".to_string()
    }

    fn message_bits(&self) -> f64 {
        self.last.len() as f64 * FP64_BITS
    }

    fn compress(&mut self, model: &[f64]) -> Msg {
        self.last.copy_from_slice(model);
        Msg::Dense(model.to_vec())
    }

    fn encode_into(&mut self, model: &[f64], out: &mut MsgBuf) {
        self.last.copy_from_slice(model);
        out.set_dense(model);
    }

    fn public_view(&self) -> &[f64] {
        &self.last
    }
}

/// The Q-GADMM stochastic uniform quantizer (sender side).
pub struct StochasticQuantizer {
    /// Previously transmitted (reconstructed) model — the quantization
    /// anchor shared with every receiver.
    prev: Vec<f64>,
    bits: u32,
    rng: Pcg64,
}

impl StochasticQuantizer {
    /// `bits` per coordinate in `[1, 32]`; `seed` makes the stochastic
    /// rounding reproducible.
    pub fn new(dim: usize, bits: u32, seed: u64) -> StochasticQuantizer {
        assert!((1..=32).contains(&bits), "quantizer bits must be in 1..=32");
        StochasticQuantizer {
            prev: vec![0.0; dim],
            bits,
            rng: Pcg64::new(seed, QUANT_STREAM),
        }
    }

    /// The per-worker constructor used by both the sequential engine and
    /// the distributed coordinator — same (seed, worker) ⇒ same rounding
    /// sequence, which keeps the two execution paths bit-identical.
    pub fn for_worker(dim: usize, bits: u32, seed: u64, worker: usize) -> StochasticQuantizer {
        let tag = ((worker as u64) << 32) | worker as u64;
        StochasticQuantizer::new(dim, bits, seed.wrapping_add(tag))
    }

    pub fn bits(&self) -> u32 {
        self.bits
    }

    /// Quantize `model` against the previously transmitted model and
    /// advance the anchor to the reconstruction. Allocating wrapper over
    /// [`StochasticQuantizer::encode_buf`], the single arithmetic path.
    pub fn encode(&mut self, model: &[f64]) -> QuantizedMsg {
        let mut buf = MsgBuf::new(model.len());
        self.encode_buf(model, &mut buf);
        match buf.to_msg() {
            Msg::Quantized(q) => q,
            _ => unreachable!("encode_buf always writes a quantized payload"),
        }
    }

    /// Allocation-free encode into a reusable buffer. Bit-identical to the
    /// historical allocating `encode`: the range fold, the finiteness
    /// check, the degenerate zero-range path (which consumes *no* RNG and
    /// leaves the anchor untouched), and the per-coordinate stochastic
    /// rounding all run in the same order — the anchor advance fuses the
    /// old `prev = msg.decode(&prev)` into the same loop, coordinate `i`
    /// reading only its own old `prev[i]` (exactly what `decode` computed).
    pub fn encode_buf(&mut self, model: &[f64], out: &mut MsgBuf) {
        assert_eq!(model.len(), self.prev.len());
        let range = model
            .iter()
            .zip(&self.prev)
            .map(|(&x, &p)| (x - p).abs())
            .fold(0.0f64, f64::max);
        // `f64::max` ignores NaN deltas, so check finiteness explicitly:
        // a diverged (NaN/inf) iterate must freeze the anchor rather than
        // decode to a fabricated finite value.
        let finite = model.iter().all(|v| v.is_finite());
        if range == 0.0 || !range.is_finite() || !finite {
            // Nothing moved (or the iterate diverged to non-finite values):
            // transmit the degenerate range; receivers keep `prev`.
            out.begin_quantized(0.0, self.bits, model.len());
            return;
        }
        out.begin_quantized(range, self.bits, model.len());
        let max_level = ((1u64 << self.bits) - 1) as f64;
        let step = 2.0 * range / max_level;
        let levels = out.levels_mut();
        for (i, (&x, p)) in model.iter().zip(self.prev.iter_mut()).enumerate() {
            let pos = (x - (*p - range)) / step;
            let lo = pos.floor();
            let frac = pos - lo;
            // Stochastic rounding: up with probability `frac`, so the
            // reconstruction is unbiased.
            let idx = lo + if self.rng.next_f64() < frac { 1.0 } else { 0.0 };
            let idx = idx.clamp(0.0, max_level) as u32;
            levels[i] = idx;
            // Advance the anchor to the reconstruction (= decode of this
            // coordinate against the old anchor).
            *p = (*p - range) + idx as f64 * step;
        }
    }
}

impl Compressor for StochasticQuantizer {
    fn describe(&self) -> String {
        format!("q{}", self.bits)
    }

    /// Wire size of every message this quantizer emits (`d·b + 64`).
    fn message_bits(&self) -> f64 {
        self.prev.len() as f64 * self.bits as f64 + RANGE_OVERHEAD_BITS
    }

    fn compress(&mut self, model: &[f64]) -> Msg {
        Msg::Quantized(self.encode(model))
    }

    fn encode_into(&mut self, model: &[f64], out: &mut MsgBuf) {
        self.encode_buf(model, out);
    }

    fn public_view(&self) -> &[f64] {
        &self.prev
    }
}

/// Receiver-side state: mirrors one sender's previously transmitted model
/// and applies incoming messages to it.
pub struct Decoder {
    prev: Vec<f64>,
}

impl Decoder {
    pub fn new(dim: usize) -> Decoder {
        Decoder {
            prev: vec![0.0; dim],
        }
    }

    /// Apply one message and return the sender's current public model.
    /// A censored slot ([`Msg::Skip`]) leaves the cached view untouched —
    /// exactly what a receiver that heard nothing would do. A layered
    /// message updates only the flat ranges its chunks cover; every stale
    /// layer keeps the cached view, per-layer `Skip` semantics.
    pub fn apply(&mut self, msg: &Msg) -> &[f64] {
        match msg {
            Msg::Dense(v) => {
                self.prev.copy_from_slice(v);
            }
            Msg::Quantized(q) => {
                q.decode_into(&mut self.prev);
            }
            Msg::Layers(chunks) => {
                for c in chunks {
                    match &c.msg {
                        Msg::Dense(v) => {
                            self.prev[c.offset..c.offset + v.len()].copy_from_slice(v);
                        }
                        Msg::Quantized(q) => {
                            q.decode_into(&mut self.prev[c.offset..c.offset + q.levels.len()]);
                        }
                        Msg::Skip => {}
                        Msg::Layers(_) => {
                            panic!("nested layered messages are not supported")
                        }
                    }
                }
            }
            Msg::Skip => {}
        }
        &self.prev
    }

    /// The current view without applying anything.
    pub fn view(&self) -> &[f64] {
        &self.prev
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_roundtrip_is_exact() {
        let mut c = DenseCompressor::new(3);
        let x = vec![1.0, -2.5, 0.25];
        let msg = c.compress(&x);
        assert_eq!(msg.payload_bits(), 3.0 * FP64_BITS);
        assert_eq!(c.message_bits(), 3.0 * FP64_BITS);
        let mut d = Decoder::new(3);
        assert_eq!(d.apply(&msg), x.as_slice());
        assert_eq!(c.public_view(), x.as_slice());
        assert_eq!(c.describe(), "dense");
    }

    #[test]
    fn non_finite_model_freezes_anchor() {
        let mut q = StochasticQuantizer::new(3, 8, 1);
        let _ = q.encode(&[1.0, 2.0, 3.0]);
        let anchor = q.public_view().to_vec();
        let msg = q.encode(&[f64::NAN, 2.0, 3.0]);
        assert_eq!(msg.range, 0.0, "NaN coordinate must freeze the anchor");
        assert_eq!(q.public_view(), anchor.as_slice());
        let msg = q.encode(&[f64::INFINITY, 0.0, 0.0]);
        assert_eq!(msg.range, 0.0, "inf coordinate must freeze the anchor");
        assert_eq!(q.public_view(), anchor.as_slice());
    }

    #[test]
    fn quantized_roundtrip_error_bounded_by_step() {
        let mut rng = Pcg64::seeded(5);
        for bits in [2u32, 4, 8, 12] {
            let mut q = StochasticQuantizer::new(16, bits, 9);
            let x = rng.normal_vec(16);
            let msg = q.encode(&x);
            let rec = q.public_view();
            let step = 2.0 * msg.range / ((1u64 << bits) - 1) as f64;
            for (xi, ri) in x.iter().zip(rec) {
                assert!(
                    (xi - ri).abs() <= step + 1e-12,
                    "b={bits}: |{xi} − {ri}| > step {step}"
                );
            }
            assert_eq!(msg.payload_bits(), 16.0 * bits as f64 + RANGE_OVERHEAD_BITS);
        }
    }

    #[test]
    fn sender_and_receiver_views_agree_bitwise() {
        let mut q = StochasticQuantizer::for_worker(8, 6, 3, 2);
        let mut d = Decoder::new(8);
        let mut rng = Pcg64::seeded(11);
        for _ in 0..20 {
            let x = rng.normal_vec(8);
            let msg = q.compress(&x);
            let seen = d.apply(&msg).to_vec();
            assert_eq!(seen, q.public_view(), "sender/receiver divergence");
        }
    }

    #[test]
    fn zero_delta_sends_degenerate_range() {
        let mut q = StochasticQuantizer::new(4, 8, 1);
        let x = vec![0.5, -0.5, 1.0, 0.0];
        let _ = q.encode(&x);
        let anchored = q.public_view().to_vec();
        let msg = q.encode(&anchored);
        assert_eq!(msg.range, 0.0);
        assert_eq!(q.public_view(), anchored.as_slice());
        let mut d = Decoder::new(4);
        // Receiver replays both messages and lands on the same anchor.
        d.apply(&Msg::Quantized(QuantizedMsg {
            range: 0.0,
            bits_per_coord: 8,
            levels: vec![0; 4],
        }));
        assert_eq!(d.view(), vec![0.0; 4].as_slice());
    }

    #[test]
    fn fixed_seed_is_reproducible() {
        let mut a = StochasticQuantizer::new(10, 4, 77);
        let mut b = StochasticQuantizer::new(10, 4, 77);
        let mut rng = Pcg64::seeded(1);
        for _ in 0..5 {
            let x = rng.normal_vec(10);
            assert_eq!(a.encode(&x), b.encode(&x));
        }
    }

    #[test]
    fn describe_labels_bits() {
        assert_eq!(StochasticQuantizer::new(2, 8, 0).describe(), "q8");
    }

    /// encode_into is compress with the allocation removed: same messages,
    /// same RNG consumption, same anchors, for dense and quantized senders
    /// (zero-delta and moving slots interleaved).
    #[test]
    fn encode_into_matches_compress_bitwise() {
        let mut rng = Pcg64::seeded(21);
        let mut qa = StochasticQuantizer::new(6, 5, 17);
        let mut qb = StochasticQuantizer::new(6, 5, 17);
        let mut da = DenseCompressor::new(6);
        let mut db = DenseCompressor::new(6);
        let mut buf = MsgBuf::new(6);
        let mut x = vec![0.0; 6];
        for round in 0..10 {
            if round % 3 != 2 {
                x = rng.normal_vec(6); // round % 3 == 2 resends ⇒ zero delta
            }
            let msg = qa.compress(&x);
            qb.encode_into(&x, &mut buf);
            assert_eq!(buf.to_msg(), msg, "round {round}");
            assert_eq!(buf.payload_bits(), msg.payload_bits());
            assert_eq!(qa.public_view(), qb.public_view(), "anchors diverged");
            let msg = da.compress(&x);
            db.encode_into(&x, &mut buf);
            assert_eq!(buf.to_msg(), msg);
            assert_eq!(buf.payload_bits(), msg.payload_bits());
            assert_eq!(da.public_view(), db.public_view());
        }
    }

    #[test]
    fn msg_buf_accounting_matches_msg() {
        let mut buf = MsgBuf::new(4);
        assert!(buf.is_skip());
        assert_eq!(buf.kind(), MsgBufKind::Skip);
        assert_eq!(buf.payload_bits(), Msg::Skip.payload_bits());
        buf.set_dense(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(buf.payload_bits(), 4.0 * FP64_BITS);
        assert_eq!(buf.to_msg(), Msg::Dense(vec![1.0, 2.0, 3.0, 4.0]));
        let q = QuantizedMsg { range: 0.5, bits_per_coord: 3, levels: vec![0, 7, 3, 1] };
        buf.set_msg(&Msg::Quantized(q.clone()));
        assert_eq!(buf.payload_bits(), q.payload_bits());
        assert_eq!(buf.to_msg(), Msg::Quantized(q));
        buf.set_skip();
        assert!(buf.is_skip());
        assert_eq!(buf.payload_bits(), 0.0);
    }

    #[test]
    fn layered_msg_bits_sum_chunks() {
        let msg = Msg::Layers(vec![
            LayerChunk { offset: 0, msg: Msg::Dense(vec![1.0, 2.0, 3.0]) },
            LayerChunk {
                offset: 5,
                msg: Msg::Quantized(QuantizedMsg {
                    range: 0.5,
                    bits_per_coord: 4,
                    levels: vec![1, 2],
                }),
            },
        ]);
        assert_eq!(msg.payload_bits(), 3.0 * FP64_BITS + 2.0 * 4.0 + RANGE_OVERHEAD_BITS);
        assert!(!msg.is_skip());
        assert_eq!(Msg::Layers(vec![]).payload_bits(), 0.0);
    }

    #[test]
    fn decoder_applies_layer_chunks_at_offsets_only() {
        let mut d = Decoder::new(6);
        d.apply(&Msg::Dense(vec![9.0; 6]));
        // Chunk covering [1, 3): the rest of the mirror must stay cached.
        let msg = Msg::Layers(vec![LayerChunk {
            offset: 1,
            msg: Msg::Dense(vec![1.0, 2.0]),
        }]);
        assert_eq!(d.apply(&msg), &[9.0, 1.0, 2.0, 9.0, 9.0, 9.0]);
        // A quantized chunk decodes against the cached slice.
        let mut q = StochasticQuantizer::new(2, 8, 3);
        // Anchor the quantizer at the mirror's current [4, 6) slice.
        let _ = q.encode(&[9.0, 9.0]);
        let qm = q.encode(&[7.0, 8.0]);
        let view = q.public_view().to_vec();
        d.apply(&Msg::Layers(vec![LayerChunk { offset: 4, msg: Msg::Quantized(qm) }]));
        assert_eq!(&d.view()[4..6], view.as_slice());
        assert_eq!(&d.view()[..4], &[9.0, 1.0, 2.0, 9.0]);
    }

    #[test]
    fn msg_buf_layers_roundtrip_and_reuse() {
        let mut buf = MsgBuf::new(0);
        buf.begin_layers();
        buf.push_layer(0).set_dense(&[1.0, 2.0]);
        buf.push_layer(7).set_dense(&[3.0]);
        assert_eq!(buf.num_layers(), 2);
        assert_eq!(buf.kind(), MsgBufKind::Layers);
        assert_eq!(buf.payload_bits(), 3.0 * FP64_BITS);
        let msg = buf.to_msg();
        assert_eq!(msg.payload_bits(), buf.payload_bits());
        // Round-trip through set_msg preserves structure.
        let mut buf2 = MsgBuf::new(0);
        buf2.set_msg(&msg);
        assert_eq!(buf2.to_msg(), msg);
        // Retract drops the last chunk; reuse rewrites in place.
        buf.retract_layer();
        assert_eq!(buf.num_layers(), 1);
        assert_eq!(buf.payload_bits(), 2.0 * FP64_BITS);
        buf.begin_layers();
        assert_eq!(buf.num_layers(), 0);
        assert_eq!(buf.payload_bits(), 0.0);
        buf.push_layer(4).set_dense(&[5.0, 6.0, 7.0]);
        match buf.to_msg() {
            Msg::Layers(chunks) => {
                assert_eq!(chunks.len(), 1);
                assert_eq!(chunks[0].offset, 4);
                assert_eq!(chunks[0].msg, Msg::Dense(vec![5.0, 6.0, 7.0]));
            }
            other => panic!("expected layered message, got {other:?}"),
        }
    }

    #[test]
    fn decode_into_is_decode_in_place() {
        let mut q = StochasticQuantizer::new(5, 6, 3);
        let msg = q.encode(&[1.0, -2.0, 0.5, 3.0, -0.25]);
        let prev = vec![0.0; 5];
        let fresh = msg.decode(&prev);
        let mut in_place = prev.clone();
        msg.decode_into(&mut in_place);
        assert_eq!(fresh, in_place);
        // Degenerate range: both forms keep the mirror untouched.
        let degenerate = QuantizedMsg { range: 0.0, bits_per_coord: 6, levels: vec![0; 5] };
        let mut kept = fresh.clone();
        degenerate.decode_into(&mut kept);
        assert_eq!(kept, fresh);
        assert_eq!(degenerate.decode(&fresh), fresh);
    }
}
