//! Per-link transmission policies: *whether* to occupy a slot and *how* to
//! encode it.
//!
//! The [`Compressor`] seam (see [`super::quantize`]) answers "how many bits
//! does a transmitted model cost"; it cannot express "send nothing this
//! slot". The censored group-ADMM follow-ups (C-GADMM / CQ-GADMM, Ben
//! Issaid et al., 2020) need exactly that: a worker whose model moved less
//! than a decaying threshold `τ·μ^k` since its last *transmitted* model
//! skips the slot entirely, and every receiver keeps its cached view. A
//! [`LinkPolicy`] composes the two decisions:
//!
//! * [`EverySlot`] — transmit every slot through an inner [`Compressor`]
//!   (dense GADMM, Q-GADMM).
//! * [`Censored`] — compare the candidate model against the inner
//!   compressor's public view; under the threshold, emit [`Msg::Skip`]
//!   (zero payload bits, no transmission slot, the inner compressor's
//!   anchor and RNG untouched); otherwise delegate to the compressor.
//!
//! One policy instance is the *sender-side* state of one worker's broadcast
//! link. The sequential engines ([`crate::optim::GroupAdmmCore`]) and the
//! distributed coordinator construct their policies through the same
//! factory functions below, so both execution paths hold bit-identical
//! wire state for the same `(seed, worker)` — the invariant the
//! distributed-equivalence tests pin. See docs/adr/003-link-policy.md.

use super::quantize::{Compressor, DenseCompressor, Msg, MsgBuf, StochasticQuantizer};
use crate::linalg::vector as vec_ops;

/// Shared validation for the censoring knobs: every entry point (spec
/// strings, JSON, engine constructors) funnels through this so the error
/// message — and the accepted domain — cannot drift between parsers.
/// `tau = 0` is legal and means "never censor" (the degeneracy the tests
/// pin: CQ-GADMM with `τ = 0` is trace-identical to Q-GADMM).
pub fn validate_censor_params(tau: f64, mu: f64) -> Result<(), String> {
    if !tau.is_finite() || tau < 0.0 {
        return Err(format!("censor tau must be finite and ≥ 0, got {tau}"));
    }
    if !(mu > 0.0 && mu < 1.0) {
        return Err(format!("censor mu must be in (0, 1), got {mu}"));
    }
    Ok(())
}

/// The decaying censoring threshold `τ·μ^k`.
///
/// Computed *incrementally* (`thr_{k+1} = thr_k · μ`) rather than via
/// `powi`, which makes the sequence monotone non-increasing by IEEE-754
/// construction — rounding a product below 1× its left factor can never
/// round back above it — a property the test suite pins. Iterations are
/// consumed in order, so the incremental form is O(1) per call.
pub struct CensorSchedule {
    tau: f64,
    mu: f64,
    k: usize,
    thr: f64,
}

impl CensorSchedule {
    /// Panics on an invalid parameter pair; parse-time entry points call
    /// [`validate_censor_params`] first and surface the same message as an
    /// error instead.
    pub fn new(tau: f64, mu: f64) -> CensorSchedule {
        if let Err(e) = validate_censor_params(tau, mu) {
            panic!("{e}");
        }
        CensorSchedule { tau, mu, k: 0, thr: tau }
    }

    pub fn tau(&self) -> f64 {
        self.tau
    }

    pub fn mu(&self) -> f64 {
        self.mu
    }

    /// Threshold `τ·μ^k`. `k` must be non-decreasing across calls (the
    /// engines consume iterations in order).
    pub fn threshold(&mut self, k: usize) -> f64 {
        assert!(
            k >= self.k,
            "censor schedule cannot rewind: asked for k={k} after k={}",
            self.k
        );
        while self.k < k {
            self.thr *= self.mu;
            self.k += 1;
        }
        self.thr
    }
}

/// Sender-side state of one worker's broadcast link: decides per slot
/// whether to transmit and how to encode.
pub trait LinkPolicy: Send {
    /// Short label for diagnostics, e.g. `"dense"`, `"q8"`,
    /// `"censor(q8,tau=1,mu=0.93)"`.
    fn describe(&self) -> String;

    /// Exact wire size of a *transmitted* message from this link. Censored
    /// slots cost 0 bits and are not billed a slot at all; the meter's
    /// structural billing reads the per-slot truth off each [`Msg`].
    fn message_bits(&self) -> f64;

    /// Decide-and-encode for iteration `k`: returns the wire [`Msg`]
    /// (possibly [`Msg::Skip`]) and advances the sender state only when
    /// the slot is actually transmitted.
    fn transmit(&mut self, k: usize, model: &[f64]) -> Msg;

    /// Allocation-free decide-and-encode: rewrite the caller's reusable
    /// [`MsgBuf`] in place. The decision logic, state advance, and payload
    /// bits are identical to [`LinkPolicy::transmit`] — skipped slots mark
    /// the buffer [`MsgBuf::is_skip`] without touching the inner
    /// compressor. The default bridges through the allocating path so
    /// third-party policies keep working.
    fn transmit_into(&mut self, k: usize, model: &[f64], out: &mut MsgBuf) {
        out.set_msg(&self.transmit(k, model));
    }

    /// The receivers' current view of this sender's model — unchanged
    /// across censored slots.
    fn public_view(&self) -> &[f64];
}

/// Transmit every slot through the inner compressor (GADMM, Q-GADMM).
pub struct EverySlot {
    inner: Box<dyn Compressor>,
}

impl EverySlot {
    pub fn new(inner: Box<dyn Compressor>) -> EverySlot {
        EverySlot { inner }
    }
}

impl LinkPolicy for EverySlot {
    fn describe(&self) -> String {
        self.inner.describe()
    }

    fn message_bits(&self) -> f64 {
        self.inner.message_bits()
    }

    fn transmit(&mut self, _k: usize, model: &[f64]) -> Msg {
        self.inner.compress(model)
    }

    fn transmit_into(&mut self, _k: usize, model: &[f64], out: &mut MsgBuf) {
        self.inner.encode_into(model, out);
    }

    fn public_view(&self) -> &[f64] {
        self.inner.public_view()
    }
}

/// Censor slots whose model change falls under `τ·μ^k` (C-GADMM /
/// CQ-GADMM): skip when `‖θ − view‖₂ < τ·μ^k`, where `view` is the model
/// the receivers currently hold for this sender. On a skip the inner
/// compressor is *not* invoked, so a quantizer's anchor and rounding RNG
/// advance only on real transmissions — which is what keeps CQ-GADMM with
/// `τ = 0` bit-identical to Q-GADMM.
pub struct Censored {
    schedule: CensorSchedule,
    inner: Box<dyn Compressor>,
}

impl Censored {
    pub fn new(inner: Box<dyn Compressor>, tau: f64, mu: f64) -> Censored {
        Censored {
            schedule: CensorSchedule::new(tau, mu),
            inner,
        }
    }
}

impl LinkPolicy for Censored {
    fn describe(&self) -> String {
        format!(
            "censor({},tau={},mu={})",
            self.inner.describe(),
            self.schedule.tau(),
            self.schedule.mu()
        )
    }

    fn message_bits(&self) -> f64 {
        self.inner.message_bits()
    }

    fn transmit(&mut self, k: usize, model: &[f64]) -> Msg {
        let thr = self.schedule.threshold(k);
        // A NaN diff compares false and therefore transmits, deferring to
        // the compressor's own non-finite handling. Skip counts are not
        // tracked here: [`super::Meter::censored`] (and the closed form
        // `k·N − TC`) is the single authoritative tally.
        if vec_ops::dist2(model, self.inner.public_view()) < thr {
            return Msg::Skip;
        }
        self.inner.compress(model)
    }

    fn transmit_into(&mut self, k: usize, model: &[f64], out: &mut MsgBuf) {
        // Same gate as `transmit` (the schedule advances exactly once per
        // slot either way); on a skip the inner compressor stays untouched.
        let thr = self.schedule.threshold(k);
        if vec_ops::dist2(model, self.inner.public_view()) < thr {
            out.set_skip();
            return;
        }
        self.inner.encode_into(model, out);
    }

    fn public_view(&self) -> &[f64] {
        self.inner.public_view()
    }
}

/// Dense full-precision links for all `n` workers (GADMM).
pub fn dense_links(dim: usize, n: usize) -> Vec<Box<dyn LinkPolicy>> {
    (0..n)
        .map(|_| Box::new(EverySlot::new(Box::new(DenseCompressor::new(dim)))) as Box<dyn LinkPolicy>)
        .collect()
}

/// Stochastically quantized links (Q-GADMM): same `(seed, worker)` ⇒ same
/// rounding stream on the sequential and distributed paths.
pub fn quant_links(dim: usize, n: usize, bits: u32, seed: u64) -> Vec<Box<dyn LinkPolicy>> {
    (0..n)
        .map(|w| {
            Box::new(EverySlot::new(Box::new(StochasticQuantizer::for_worker(
                dim, bits, seed, w,
            )))) as Box<dyn LinkPolicy>
        })
        .collect()
}

/// Censored dense links (C-GADMM).
pub fn censored_dense_links(dim: usize, n: usize, tau: f64, mu: f64) -> Vec<Box<dyn LinkPolicy>> {
    (0..n)
        .map(|_| {
            Box::new(Censored::new(Box::new(DenseCompressor::new(dim)), tau, mu))
                as Box<dyn LinkPolicy>
        })
        .collect()
}

/// Censored quantized links (CQ-GADMM).
pub fn censored_quant_links(
    dim: usize,
    n: usize,
    bits: u32,
    tau: f64,
    mu: f64,
    seed: u64,
) -> Vec<Box<dyn LinkPolicy>> {
    (0..n)
        .map(|w| {
            Box::new(Censored::new(
                Box::new(StochasticQuantizer::for_worker(dim, bits, seed, w)),
                tau,
                mu,
            )) as Box<dyn LinkPolicy>
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::FP64_BITS;

    #[test]
    fn schedule_decays_and_validates() {
        let mut s = CensorSchedule::new(2.0, 0.5);
        assert_eq!(s.threshold(0), 2.0);
        assert_eq!(s.threshold(1), 1.0);
        assert_eq!(s.threshold(3), 0.25);
        assert_eq!(s.threshold(3), 0.25, "same k twice is fine");
        assert!(validate_censor_params(-1.0, 0.5).is_err());
        assert!(validate_censor_params(1.0, 0.0).is_err());
        assert!(validate_censor_params(1.0, 1.0).is_err());
        assert!(validate_censor_params(f64::NAN, 0.5).is_err());
        assert!(validate_censor_params(0.0, 0.93).is_ok(), "tau=0 disables censoring");
    }

    #[test]
    #[should_panic(expected = "cannot rewind")]
    fn schedule_rejects_rewind() {
        let mut s = CensorSchedule::new(1.0, 0.5);
        let _ = s.threshold(5);
        let _ = s.threshold(4);
    }

    #[test]
    fn every_slot_is_the_plain_compressor() {
        let mut link = EverySlot::new(Box::new(DenseCompressor::new(2)));
        let msg = link.transmit(0, &[1.0, -2.0]);
        assert_eq!(msg.payload_bits(), 2.0 * FP64_BITS);
        assert_eq!(link.public_view(), &[1.0, -2.0]);
        assert_eq!(link.describe(), "dense");
    }

    #[test]
    fn censored_link_skips_small_moves_and_freezes_view() {
        // tau=1, mu=0.5: thresholds 1.0, 0.5, 0.25, ...
        let mut link = Censored::new(Box::new(DenseCompressor::new(2)), 1.0, 0.5);
        // k=0: ‖(0.3,0.4)‖ = 0.5 < 1.0 → skip, view frozen at the origin.
        let msg = link.transmit(0, &[0.3, 0.4]);
        assert!(msg.is_skip());
        assert_eq!(msg.payload_bits(), 0.0);
        assert_eq!(link.public_view(), &[0.0, 0.0]);
        // k=1: ‖(0.3,0.4)‖ = 0.5 ≥ 0.5 → transmit, view catches up.
        let msg = link.transmit(1, &[0.3, 0.4]);
        assert!(!msg.is_skip());
        assert_eq!(link.public_view(), &[0.3, 0.4]);
        assert!(link.describe().starts_with("censor(dense"));
    }

    #[test]
    fn tau_zero_never_censors() {
        let mut link = Censored::new(Box::new(DenseCompressor::new(1)), 0.0, 0.93);
        for k in 0..10 {
            assert!(!link.transmit(k, &[0.0]).is_skip(), "slot {k}");
        }
    }

    #[test]
    fn censored_quantizer_rng_untouched_on_skip() {
        // Two quantized links with the same seed: one censors its first
        // slot, then both transmit the same model — the rounding streams
        // must still agree because a skip consumes no RNG.
        let mk = || Box::new(StochasticQuantizer::for_worker(4, 4, 9, 0));
        // k=0 threshold 0.3 > ‖(0.1,0.2,−0.1,0)‖ ≈ 0.245 ⇒ censored.
        let mut a = Censored::new(mk(), 0.3, 0.5);
        let mut b = EverySlot::new(mk());
        assert!(a.transmit(0, &[0.1, 0.2, -0.1, 0.0]).is_skip());
        let x = [1.5, -2.5, 0.5, 3.0];
        // k=1 threshold is 0.15; ‖x‖ ≈ 4.2 ⇒ transmit.
        let ma = a.transmit(1, &x);
        let mb = b.transmit(1, &x);
        assert!(!ma.is_skip());
        assert_eq!(a.public_view(), b.public_view(), "rounding streams diverged");
        assert_eq!(ma.payload_bits(), mb.payload_bits());
    }

    #[test]
    fn factories_build_one_link_per_worker() {
        assert_eq!(dense_links(3, 4).len(), 4);
        assert_eq!(quant_links(3, 6, 8, 1).len(), 6);
        assert_eq!(censored_dense_links(3, 4, 1.0, 0.93).len(), 4);
        let links = censored_quant_links(3, 4, 8, 1.0, 0.93, 7);
        assert_eq!(links.len(), 4);
        assert_eq!(links[0].message_bits(), 3.0 * 8.0 + 64.0);
    }
}
