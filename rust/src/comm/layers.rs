//! Layer-scheduled transmission: the L-FGADMM communication pattern.
//!
//! L-FGADMM (Elgabli et al., 2019) cuts communication by exchanging
//! *large layers less often*: layer `ℓ` of a block-structured model
//! travels only every `period_ℓ` rounds. Between transmissions every
//! receiver keeps its last public copy of that layer — exactly the
//! [`Msg::Skip`] semantics the censored variants already use, applied
//! per layer instead of per model, and charged 0 bits.
//!
//! [`LayerScheduled`] composes over the existing [`LinkPolicy`] seam: it
//! holds one *inner* policy per layer (dense, quantized, or censored —
//! anything), consults the schedule `k mod period_ℓ == 0`, and assembles
//! the due layers' encodings into one [`Msg::Layers`] broadcast. A layer
//! that is due but censored by its inner policy is simply absent from
//! the chunk list; a slot where nothing travels at all degenerates to
//! [`Msg::Skip`]. Iteration 0 transmits every layer (`0 mod p == 0`), so
//! receivers are never left with uninitialized state.
//!
//! The schedule is a pure function of `(k, periods)` — no data-dependent
//! state — which is what keeps the sequential engines, the channel
//! coordinator, and the TCP transport bit-identical for `lfgadmm:` specs
//! (see docs/adr/009-block-layout-lfgadmm.md).

use super::policy::{Censored, EverySlot, LinkPolicy};
use super::quantize::{DenseCompressor, LayerChunk, Msg, MsgBuf, StochasticQuantizer};
use crate::linalg::BlockLayout;

/// Per-layer seed perturbation for quantized layer links (golden-ratio
/// multiplier keeps distinct layers on distinct rounding streams).
const LAYER_SEED_MIX: u64 = 0x9e37_79b9_7f4a_7c15;

/// Shared validation for a layer plan: block lengths must be non-empty,
/// positive, and sum to the model dimension; periods must be ≥ 1, one per
/// block. Every entry point (spec strings, JSON, engine constructors)
/// funnels through this so the accepted domain cannot drift.
pub fn validate_layer_plan(lens: &[usize], periods: &[usize], dim: usize) -> Result<(), String> {
    if lens.is_empty() {
        return Err("layer plan needs at least one block".to_string());
    }
    if lens.iter().any(|&l| l == 0) {
        return Err("layer plan blocks must be non-empty".to_string());
    }
    let total: usize = lens.iter().sum();
    if total != dim {
        return Err(format!(
            "layer lengths sum to {total} but the model dimension is {dim}"
        ));
    }
    if periods.len() != lens.len() {
        return Err(format!(
            "{} layers but {} periods",
            lens.len(),
            periods.len()
        ));
    }
    if periods.iter().any(|&p| p == 0) {
        return Err("layer periods must be ≥ 1".to_string());
    }
    Ok(())
}

/// Sender-side state of one worker's layer-scheduled broadcast link.
pub struct LayerScheduled {
    layout: BlockLayout,
    periods: Vec<usize>,
    /// One inner policy per layer, operating on that layer's flat slice.
    inner: Vec<Box<dyn LinkPolicy>>,
    /// Assembled full-dimension public view: per layer, what receivers
    /// currently hold (fresh where transmitted, stale elsewhere).
    view: Vec<f64>,
}

impl LayerScheduled {
    pub fn new(
        layout: BlockLayout,
        periods: Vec<usize>,
        inner: Vec<Box<dyn LinkPolicy>>,
    ) -> LayerScheduled {
        if let Err(e) = validate_layer_plan(layout.lens(), &periods, layout.dim()) {
            panic!("{e}");
        }
        assert_eq!(inner.len(), layout.num_blocks(), "one inner policy per layer");
        for (l, link) in inner.iter().enumerate() {
            assert_eq!(
                link.public_view().len(),
                layout.len(l),
                "inner policy {l} sized for the wrong layer"
            );
        }
        let view = vec![0.0; layout.dim()];
        LayerScheduled { layout, periods, inner, view }
    }

    /// Whether layer `l` is scheduled for transmission at iteration `k`.
    pub fn due(&self, k: usize, l: usize) -> bool {
        k % self.periods[l] == 0
    }

    pub fn layout(&self) -> &BlockLayout {
        &self.layout
    }

    pub fn periods(&self) -> &[usize] {
        &self.periods
    }
}

impl LinkPolicy for LayerScheduled {
    fn describe(&self) -> String {
        let parts: Vec<String> = (0..self.layout.num_blocks())
            .map(|l| format!("{}@{}:{}", self.layout.len(l), self.periods[l], self.inner[l].describe()))
            .collect();
        format!("layers({})", parts.join(","))
    }

    /// Wire size with every layer transmitted (the k = 0 slot); scheduled
    /// slots are smaller, and the meter reads the per-slot truth off each
    /// message.
    fn message_bits(&self) -> f64 {
        self.inner.iter().map(|p| p.message_bits()).sum()
    }

    fn transmit(&mut self, k: usize, model: &[f64]) -> Msg {
        assert_eq!(model.len(), self.layout.dim(), "model does not match layout dim");
        let mut chunks = Vec::new();
        for l in 0..self.layout.num_blocks() {
            if k % self.periods[l] != 0 {
                continue;
            }
            let msg = self.inner[l].transmit(k, self.layout.block(model, l));
            self.view[self.layout.range(l)].copy_from_slice(self.inner[l].public_view());
            if !msg.is_skip() {
                chunks.push(LayerChunk { offset: self.layout.offset(l), msg });
            }
        }
        if chunks.is_empty() {
            Msg::Skip
        } else {
            Msg::Layers(chunks)
        }
    }

    /// Same schedule, same inner calls, same state advance as
    /// [`LinkPolicy::transmit`], writing into the reusable buffer: due
    /// layers are pushed as chunks, inner-censored ones retracted, and a
    /// chunkless slot degenerates to a skip.
    fn transmit_into(&mut self, k: usize, model: &[f64], out: &mut MsgBuf) {
        assert_eq!(model.len(), self.layout.dim(), "model does not match layout dim");
        out.begin_layers();
        for l in 0..self.layout.num_blocks() {
            if k % self.periods[l] != 0 {
                continue;
            }
            let censored = {
                let chunk = out.push_layer(self.layout.offset(l));
                self.inner[l].transmit_into(k, self.layout.block(model, l), chunk);
                chunk.is_skip()
            };
            if censored {
                out.retract_layer();
            }
            self.view[self.layout.range(l)].copy_from_slice(self.inner[l].public_view());
        }
        if out.num_layers() == 0 {
            out.set_skip();
        }
    }

    fn public_view(&self) -> &[f64] {
        &self.view
    }
}

/// Build per-layer inner policies for all `n` workers via `mk(worker,
/// layer, layer_len)` and wrap them in [`LayerScheduled`].
fn build_links(
    layout: &BlockLayout,
    periods: &[usize],
    n: usize,
    mk: impl Fn(usize, usize, usize) -> Box<dyn LinkPolicy>,
) -> Vec<Box<dyn LinkPolicy>> {
    (0..n)
        .map(|w| {
            let inner: Vec<Box<dyn LinkPolicy>> = (0..layout.num_blocks())
                .map(|l| mk(w, l, layout.len(l)))
                .collect();
            Box::new(LayerScheduled::new(layout.clone(), periods.to_vec(), inner))
                as Box<dyn LinkPolicy>
        })
        .collect()
}

/// Dense layer-scheduled links for all `n` workers (L-FGADMM).
pub fn layer_dense_links(
    layout: &BlockLayout,
    periods: &[usize],
    n: usize,
) -> Vec<Box<dyn LinkPolicy>> {
    build_links(layout, periods, n, |_, _, len| {
        Box::new(EverySlot::new(Box::new(DenseCompressor::new(len))))
    })
}

/// Quantized layer-scheduled links: layer `l` of worker `w` quantizes on
/// its own `(seed, w, l)` rounding stream, so sequential and distributed
/// runs stay bit-identical per layer.
pub fn layer_quant_links(
    layout: &BlockLayout,
    periods: &[usize],
    n: usize,
    bits: u32,
    seed: u64,
) -> Vec<Box<dyn LinkPolicy>> {
    build_links(layout, periods, n, |w, l, len| {
        let layer_seed = seed.wrapping_add((l as u64).wrapping_mul(LAYER_SEED_MIX));
        Box::new(EverySlot::new(Box::new(StochasticQuantizer::for_worker(
            len, bits, layer_seed, w,
        ))))
    })
}

/// Censored dense layer-scheduled links: each layer carries its own
/// decaying censor gate over the layer slice.
pub fn layer_censored_dense_links(
    layout: &BlockLayout,
    periods: &[usize],
    n: usize,
    tau: f64,
    mu: f64,
) -> Vec<Box<dyn LinkPolicy>> {
    build_links(layout, periods, n, |_, _, len| {
        Box::new(Censored::new(Box::new(DenseCompressor::new(len)), tau, mu))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::quantize::Decoder;
    use crate::comm::FP64_BITS;
    use crate::util::rng::Pcg64;

    fn dense_link(lens: Vec<usize>, periods: Vec<usize>) -> LayerScheduled {
        let layout = BlockLayout::new(lens);
        let inner: Vec<Box<dyn LinkPolicy>> = layout
            .lens()
            .iter()
            .map(|&len| {
                Box::new(EverySlot::new(Box::new(DenseCompressor::new(len))))
                    as Box<dyn LinkPolicy>
            })
            .collect();
        LayerScheduled::new(layout, periods, inner)
    }

    #[test]
    fn validate_layer_plan_domains() {
        assert!(validate_layer_plan(&[3, 2], &[1, 2], 5).is_ok());
        assert!(validate_layer_plan(&[], &[], 0).is_err());
        assert!(validate_layer_plan(&[3, 0], &[1, 1], 3).is_err());
        assert!(validate_layer_plan(&[3, 2], &[1, 1], 6).is_err());
        assert!(validate_layer_plan(&[3, 2], &[1], 5).is_err());
        assert!(validate_layer_plan(&[3, 2], &[1, 0], 5).is_err());
    }

    #[test]
    fn schedule_transmits_every_layer_at_k0_and_by_period_after() {
        let mut link = dense_link(vec![2, 3], vec![1, 2]);
        assert!(link.due(0, 0) && link.due(0, 1), "all layers due at k=0");
        let model = [1.0, 2.0, 3.0, 4.0, 5.0];
        match link.transmit(0, &model) {
            Msg::Layers(chunks) => {
                assert_eq!(chunks.len(), 2);
                assert_eq!(chunks[0].offset, 0);
                assert_eq!(chunks[1].offset, 2);
                assert_eq!(chunks[1].msg, Msg::Dense(vec![3.0, 4.0, 5.0]));
            }
            other => panic!("expected layered message, got {other:?}"),
        }
        assert_eq!(link.public_view(), model.as_slice());
        // k=1: only layer 0 (period 1) travels; layer 1 goes stale.
        let model2 = [9.0, 8.0, 7.0, 6.0, 5.0];
        let msg = link.transmit(1, &model2);
        assert_eq!(msg.payload_bits(), 2.0 * FP64_BITS);
        assert_eq!(link.public_view(), &[9.0, 8.0, 3.0, 4.0, 5.0]);
        // k=2: both due again.
        let msg = link.transmit(2, &model2);
        assert_eq!(msg.payload_bits(), 5.0 * FP64_BITS);
        assert_eq!(link.public_view(), model2.as_slice());
    }

    #[test]
    fn receiver_decoder_tracks_assembled_view() {
        let mut link = dense_link(vec![2, 2], vec![1, 3]);
        let mut dec = Decoder::new(4);
        let mut rng = Pcg64::seeded(7);
        for k in 0..10 {
            let model = rng.normal_vec(4);
            let msg = link.transmit(k, &model);
            dec.apply(&msg);
            assert_eq!(dec.view(), link.public_view(), "k={k}");
        }
    }

    #[test]
    fn transmit_into_matches_transmit_bitwise() {
        let layout = vec![3, 2, 1];
        let periods = vec![1, 2, 3];
        let mut a = dense_link(layout.clone(), periods.clone());
        let mut b = dense_link(layout, periods);
        let mut buf = MsgBuf::new(0);
        let mut rng = Pcg64::seeded(13);
        for k in 0..12 {
            let model = rng.normal_vec(6);
            let msg = a.transmit(k, &model);
            b.transmit_into(k, &model, &mut buf);
            assert_eq!(buf.to_msg(), msg, "k={k}");
            assert_eq!(buf.payload_bits(), msg.payload_bits(), "k={k}");
            assert_eq!(a.public_view(), b.public_view(), "views diverged at k={k}");
        }
    }

    #[test]
    fn censored_layer_is_absent_and_all_censored_slot_skips() {
        // Inner censors with a huge threshold: every due layer is censored
        // until the threshold decays, so early slots are pure skips.
        let layout = BlockLayout::new(vec![2, 2]);
        let inner: Vec<Box<dyn LinkPolicy>> = vec![
            Box::new(Censored::new(Box::new(DenseCompressor::new(2)), 1e9, 0.5)),
            Box::new(EverySlot::new(Box::new(DenseCompressor::new(2)))),
        ];
        let mut link = LayerScheduled::new(layout, vec![1, 2], inner);
        // k=0: layer 0 censored, layer 1 transmits → one chunk.
        let msg = link.transmit(0, &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(msg.payload_bits(), 2.0 * FP64_BITS);
        assert_eq!(link.public_view(), &[0.0, 0.0, 3.0, 4.0]);
        // k=1: only layer 0 due, censored → the slot degenerates to Skip.
        let msg = link.transmit(1, &[1.0, 2.0, 3.0, 4.0]);
        assert!(msg.is_skip());
        assert_eq!(msg.payload_bits(), 0.0);
        // Allocation-free path agrees.
        let mut buf = MsgBuf::new(0);
        link.transmit_into(2, &[1.0, 2.0, 3.0, 4.0], &mut buf);
        assert!(buf.is_skip());
    }

    #[test]
    fn quantized_layers_stay_on_distinct_streams() {
        let layout = BlockLayout::new(vec![2, 2]);
        let links = layer_quant_links(&layout, &[1, 1], 2, 8, 5);
        assert_eq!(links.len(), 2);
        let mut link = links.into_iter().next().unwrap();
        let msg = link.transmit(0, &[0.5, -0.5, 1.5, -1.5]);
        match msg {
            Msg::Layers(chunks) => {
                assert_eq!(chunks.len(), 2);
                for c in &chunks {
                    assert!(matches!(c.msg, Msg::Quantized(_)));
                }
                // d·b + range overhead per chunk.
                let bits: f64 = chunks.iter().map(|c| c.msg.payload_bits()).sum();
                assert_eq!(bits, 2.0 * (2.0 * 8.0 + 64.0));
            }
            other => panic!("expected layered message, got {other:?}"),
        }
    }

    #[test]
    fn single_block_period_one_has_whole_model_bits() {
        // The degeneracy the refactor pins rely on: one block, period 1
        // transmits the full model every slot at dense cost.
        let mut link = dense_link(vec![4], vec![1]);
        for k in 0..5 {
            let model = [k as f64; 4];
            let msg = link.transmit(k, &model);
            assert_eq!(msg.payload_bits(), 4.0 * FP64_BITS);
            assert_eq!(link.public_view(), model.as_slice());
        }
    }

    #[test]
    fn factories_build_one_link_per_worker() {
        let layout = BlockLayout::new(vec![3, 2]);
        assert_eq!(layer_dense_links(&layout, &[1, 2], 4).len(), 4);
        assert_eq!(layer_censored_dense_links(&layout, &[1, 2], 6, 1.0, 0.9).len(), 6);
        let links = layer_quant_links(&layout, &[1, 2], 2, 4, 3);
        assert_eq!(links[0].message_bits(), (3.0 * 4.0 + 64.0) + (2.0 * 4.0 + 64.0));
        assert!(links[0].describe().starts_with("layers(3@1:q4,2@2:q4"));
    }

    #[test]
    #[should_panic(expected = "does not match layout dim")]
    fn wrong_dimension_rejected() {
        // Layout of dim 5; transmitting a dim-6 model must panic.
        let mut link = dense_link(vec![3, 2], vec![1, 1]);
        let _ = link.transmit(0, &[0.0; 6]);
    }
}
