//! Regenerates **Fig. 4** (logistic regression, synthetic, N=24) and
//! **Fig. 5** (logistic regression, Derm surrogate, N=10).

use gadmm::experiments::curves::{self, Figure};

fn main() {
    gadmm::util::logging::init();
    let fast = std::env::var("GADMM_BENCH_FAST").is_ok();
    let max_iters = if fast { 30_000 } else { 300_000 };
    for fig in [Figure::Fig4, Figure::Fig5] {
        let t0 = std::time::Instant::now();
        let out = curves::run(fig, 1e-4, max_iters, 1);
        println!("{}", out.rendered);
        println!("[{} completed in {:.2?}]", fig.name(), t0.elapsed());
    }
}
