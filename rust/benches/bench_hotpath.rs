//! Microbenchmarks of the hot path: the per-iteration cost of every layer-3
//! compute kernel (local solves, gradient evaluations, full GADMM
//! iterations) at paper scale. This is the §Perf baseline/after harness.

use gadmm::comm::Meter;
use gadmm::data::synthetic;
use gadmm::linalg::{Cholesky, Matrix};
use gadmm::model::{LocalLoss, Problem};
use gadmm::optim::{Engine, Gadmm};
use gadmm::topology::UnitCosts;
use gadmm::util::bench::{bench, black_box};
use gadmm::util::rng::Pcg64;

fn main() {
    println!("== hot-path microbenchmarks (paper scale: N=24, 1200x50) ==");
    let mut rng = Pcg64::seeded(1);

    // Dense kernels.
    let d = 50;
    let a = {
        let mut m = Matrix::zeros(d, d);
        for v in &mut m.data {
            *v = rng.normal();
        }
        let mut g = m.gram();
        g.add_diag(d as f64);
        g
    };
    let x = rng.normal_vec(d);
    println!("{}", bench("gemv d=50", 100, 2000, || black_box(a.matvec(&x))).report());
    let chol = Cholesky::factor(&a).unwrap();
    println!(
        "{}",
        bench("cholesky factor d=50", 10, 500, || black_box(Cholesky::factor(&a).unwrap())).report()
    );
    println!(
        "{}",
        bench("cholesky solve d=50 (cached factor)", 100, 2000, || black_box(chol.solve(&x)))
            .report()
    );

    // Worker-local solves at the synthetic shard shape (50x50).
    let ds = synthetic::linreg_default(1);
    let p = Problem::from_dataset(&ds, 24);
    let q = rng.normal_vec(50);
    let warm = vec![0.0; 50];
    let c = 2.0 * 3.0 * p.data_weight;
    // Warm the factor cache, then measure the steady-state solve.
    let _ = p.losses[0].prox_argmin(&q, c, &warm);
    println!(
        "{}",
        bench("linreg prox (cached factor, m=50 d=50)", 100, 2000, || {
            black_box(p.losses[0].prox_argmin(&q, c, &warm))
        })
        .report()
    );
    let mut g = vec![0.0; 50];
    println!(
        "{}",
        bench("linreg grad (m=50 d=50)", 100, 2000, || {
            p.losses[0].grad_into(&x, &mut g);
            black_box(&g);
        })
        .report()
    );

    let dslog = synthetic::logreg_default(1);
    let plog = Problem::from_dataset(&dslog, 24);
    let small_q: Vec<f64> = q.iter().map(|v| 0.1 * v).collect();
    let warm_log = plog.theta_star.clone();
    println!(
        "{}",
        bench("logreg prox newton (warm, m=50 d=50)", 20, 300, || {
            black_box(plog.losses[0].prox_argmin(&small_q, 0.3 * plog.data_weight, &warm_log))
        })
        .report()
    );

    // Full engine iterations at paper scale.
    let costs = UnitCosts;
    let mut engine = Gadmm::new(&p, 3.0);
    let mut meter = Meter::new(&costs);
    let mut k = 0usize;
    println!(
        "{}",
        bench("GADMM full iteration (N=24, d=50)", 5, 300, || {
            engine.step(k, &mut meter);
            k += 1;
        })
        .report()
    );
    println!(
        "{}",
        bench("objective eval (N=24, d=50)", 20, 500, || black_box(engine.objective())).report()
    );
}
