//! Regenerates **Fig. 7** (D-GADMM vs GADMM under a time-varying topology,
//! N=50, τ=15, 250×250 m²) and **Fig. 8** (D-GADMM(τ=1) vs GADMM vs
//! standard parameter-server ADMM, N=24), plus the dual-handling ablation
//! the paper leaves unspecified (DESIGN.md §Substitutions).

use gadmm::config::DatasetKind;
use gadmm::experiments::{fig7, fig8};
use gadmm::model::Problem;
use gadmm::optim::{run, Dgadmm, DualHandling, RechainMode, RunOptions};
use gadmm::topology::{EnergyCostModel, Placement};
use gadmm::util::rng::Pcg64;

fn main() {
    gadmm::util::logging::init();
    let fast = std::env::var("GADMM_BENCH_FAST").is_ok();
    let (n7, n8) = if fast { (10, 10) } else { (50, 24) };

    let t0 = std::time::Instant::now();
    let out7 = fig7::run(n7, 3.0, 15, 1e-4, 100_000, 2);
    println!(
        "fig7 (N={n7}, tau=15): GADMM iters={:?} energy={:.3e} | D-GADMM iters={:?} energy={:.3e}",
        out7.gadmm.iters_to_target(),
        out7.gadmm.energy_to_target().unwrap_or(f64::NAN),
        out7.dgadmm.iters_to_target(),
        out7.dgadmm.energy_to_target().unwrap_or(f64::NAN)
    );
    println!("[fig7 completed in {:.2?}]", t0.elapsed());

    let t0 = std::time::Instant::now();
    let out8 = fig8::run(n8, 3.0, 1e-4, 100_000, 3);
    println!("{}", out8.rendered);
    println!("[fig8 completed in {:.2?}]", t0.elapsed());

    // Ablation: dual handling across re-chains (τ=1, free mode).
    println!("\n== ablation: D-GADMM dual handling across re-chains (τ=1) ==");
    let ds = DatasetKind::SyntheticLinreg.build(1);
    let p = Problem::from_dataset(&ds, n8);
    let mut rng = Pcg64::seeded(9);
    let placement = Placement::random(n8, 250.0, &mut rng);
    let costs = EnergyCostModel::new(&placement, placement.central_worker());
    let opts = RunOptions::with_target(1e-4, 50_000);
    for (dh, name) in [
        (DualHandling::Reuse, "reuse (eq. 90 literal)"),
        (DualHandling::Rebase, "rebase (momentum transfer)"),
        (DualHandling::Reinit, "reinit (feasibility sweep)"),
    ] {
        let mut e = Dgadmm::new(&p, 3.0, 1, RechainMode::Free, &costs, 42).with_dual_handling(dh);
        let t = run(&mut e, &p, &costs, &opts);
        println!(
            "  {name:<28} iters={:<8} final_err={:.2e}",
            t.iters_to_target().map(|k| k.to_string()).unwrap_or_else(|| "—".into()),
            t.final_error()
        );
    }
}
