//! Regenerates **Fig. 2** (linear regression, synthetic, N=24, GADMM
//! ρ∈{3,5,7} vs all baselines) and **Fig. 3** (linear regression, Body-Fat
//! surrogate, N=10): objective error / TC / running-time summaries.

use gadmm::experiments::curves::{self, Figure};

fn main() {
    gadmm::util::logging::init();
    let fast = std::env::var("GADMM_BENCH_FAST").is_ok();
    let max_iters = if fast { 30_000 } else { 300_000 };
    for fig in [Figure::Fig2, Figure::Fig3] {
        let t0 = std::time::Instant::now();
        let out = curves::run(fig, 1e-4, max_iters, 1);
        println!("{}", out.rendered);
        println!("[{} completed in {:.2?}]", fig.name(), t0.elapsed());
    }
}
