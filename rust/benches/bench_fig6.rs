//! Regenerates **Fig. 6**: CDFs of energy-model TC over random topologies
//! (panels a: linreg, b: logreg; 24 workers in 10×10 m²), plus panel c —
//! the GADMM average-consensus-violation (ACV) curve on logistic
//! regression with 4 workers. Default 1000 draws; `GADMM_BENCH_FAST=1`
//! uses 50.

use gadmm::config::DatasetKind;
use gadmm::experiments::fig6;

fn main() {
    gadmm::util::logging::init();
    let fast = std::env::var("GADMM_BENCH_FAST").is_ok();
    let draws = if fast { 50 } else { 1000 };
    for kind in [DatasetKind::SyntheticLinreg, DatasetKind::SyntheticLogreg] {
        let t0 = std::time::Instant::now();
        let out = fig6::run_panel(kind, 24, draws, 1e-4, 300_000, 1);
        println!("{} ({draws} draws):", out.panel);
        for (name, cdf) in &out.cdfs {
            if cdf.values.is_empty() {
                println!("  {name:<22} did not converge");
            } else {
                println!(
                    "  {name:<22} energy TC p10={:.3e} median={:.3e} p90={:.3e} ({} samples)",
                    cdf.quantile(0.1),
                    cdf.quantile(0.5),
                    cdf.quantile(0.9),
                    cdf.values.len()
                );
            }
        }
        println!("[{} completed in {:.2?}]", out.panel, t0.elapsed());
    }
    let (trace, _) = fig6::run_acv(1e-4, 300_000, 1);
    println!(
        "fig6c: iters_to_1e-4 = {:?}, ACV at convergence = {:.3e}",
        trace.iters_to_target(),
        trace.records.last().map(|r| r.acv).unwrap_or(f64::NAN)
    );
}
