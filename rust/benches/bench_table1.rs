//! Regenerates **Table 1**: iterations and total communication cost to
//! reach objective error 1e−4 on the real-dataset surrogates for
//! N ∈ {14, 20, 24, 26}, comparing LAG-PS, LAG-WK, GADMM and GD.
//! `GADMM_BENCH_FAST=1` shrinks the grid for smoke runs.

fn main() {
    gadmm::util::logging::init();
    let fast = std::env::var("GADMM_BENCH_FAST").is_ok();
    let workers: &[usize] = if fast { &[14] } else { &[14, 20, 24, 26] };
    let max_iters = if fast { 50_000 } else { 300_000 };
    let t0 = std::time::Instant::now();
    let out = gadmm::experiments::table1::run(workers, 1e-4, max_iters, 1);
    println!("{}", out.rendered);
    println!("[bench_table1 completed in {:.2?}]", t0.elapsed());
}
