//! Regenerates the **Q-GADMM comparison**: total transmitted bits to reach
//! objective error 1e−4, GADMM vs Q-GADMM at b ∈ {2, 4, 8}, paper-scale
//! synthetic linear regression (N=24, 1200×50) plus the logistic task.
//! `GADMM_BENCH_FAST=1` shrinks the sweep for smoke runs.

use gadmm::config::DatasetKind;
use gadmm::experiments::qgadmm;

fn main() {
    gadmm::util::logging::init();
    let fast = std::env::var("GADMM_BENCH_FAST").is_ok();
    let bits: &[u32] = if fast { &[8] } else { &[2, 4, 8] };
    let max_iters = if fast { 50_000 } else { 300_000 };
    let t0 = std::time::Instant::now();
    for (kind, rho) in [
        (DatasetKind::SyntheticLinreg, 5.0),
        (DatasetKind::SyntheticLogreg, 3.0),
    ] {
        let out = qgadmm::run(kind, 24, rho, bits, 1e-4, max_iters, 1);
        println!("{}", out.rendered);
    }
    println!("[bench_qgadmm completed in {:.2?}]", t0.elapsed());
}
