//! Session-layer integration tests: spec round-trips, registry
//! completeness against the engine roster, spec-built engines matching
//! direct construction (the golden-equivalence guarantee behind the
//! figure rewrites), sweep determinism across thread counts, and the
//! spec-driven coordinator entry point.

use gadmm::config::DatasetKind;
use gadmm::coordinator;
use gadmm::data::synthetic;
use gadmm::model::Problem;
use gadmm::optim::{
    self, Gadmm, Iag, IagOrder, Lag, LagVariant, Qgadmm, RunOptions,
};
use gadmm::runtime::{LocalSolver, NativeSolver};
use gadmm::session::{AlgoSpec, CsvSink, MemorySink, SweepRunner, SweepSpec, TraceSink};
use gadmm::topology::chain::Chain;
use gadmm::topology::UnitCosts;
use gadmm::util::rng::Pcg64;

fn small_problem(workers: usize, seed: u64) -> Problem {
    let ds = synthetic::linreg(80, 5, &mut Pcg64::seeded(seed));
    Problem::from_dataset(&ds, workers)
}

#[test]
fn every_registry_spec_round_trips_and_builds() {
    let problem = small_problem(4, 1);
    for spec in AlgoSpec::registry() {
        // CLI-string round trip.
        assert_eq!(AlgoSpec::parse(&spec.spec_string()).unwrap(), spec);
        // JSON round trip, through the actual serializer and parser.
        let text = spec.to_json().to_string_pretty();
        let parsed = gadmm::util::json::parse(&text).unwrap();
        assert_eq!(AlgoSpec::from_json(&parsed).unwrap(), spec);
        // The registry factory builds a runnable engine.
        let mut engine = spec.build(&problem, 3);
        let trace = optim::run(
            &mut *engine,
            &problem,
            &UnitCosts,
            &RunOptions::with_target(1e-1, 50),
        );
        assert!(!trace.records.is_empty(), "{spec}");
    }
}

#[test]
fn spec_builds_match_direct_construction() {
    // The figure rewrites lean on this: an engine built from a spec takes
    // exactly the same deterministic path as one built by hand.
    let problem = small_problem(6, 2);
    let opts = RunOptions::with_target(1e-5, 2_000);
    let costs = UnitCosts;
    let seed = 11;

    let via_spec = |spec: AlgoSpec| optim::run(&mut *spec.build(&problem, seed), &problem, &costs, &opts);

    let direct_gadmm = optim::run(&mut Gadmm::new(&problem, 3.0), &problem, &costs, &opts);
    assert!(via_spec(AlgoSpec::Gadmm { rho: 3.0, fault: 0.0, threads: 1 }).same_path(&direct_gadmm));

    let direct_qgadmm =
        optim::run(&mut Qgadmm::new(&problem, 3.0, 8, seed), &problem, &costs, &opts);
    assert!(
        via_spec(AlgoSpec::Qgadmm { rho: 3.0, bits: 8, fault: 0.0, threads: 1 }).same_path(&direct_qgadmm)
    );

    let mut lag = Lag::new(&problem, LagVariant::Wk);
    lag.xi = 0.02;
    let direct_lag = optim::run(&mut lag, &problem, &costs, &opts);
    assert!(via_spec(AlgoSpec::Lag { variant: LagVariant::Wk, xi: 0.02 }).same_path(&direct_lag));

    let direct_iag = optim::run(
        &mut Iag::new(&problem, IagOrder::RandomWeighted, seed),
        &problem,
        &costs,
        &opts,
    );
    assert!(via_spec(AlgoSpec::Iag { order: IagOrder::RandomWeighted }).same_path(&direct_iag));
}

#[test]
fn sweep_is_deterministic_across_thread_counts() {
    let spec = SweepSpec {
        algos: vec![AlgoSpec::Gadmm { rho: 3.0, fault: 0.0, threads: 1 }, AlgoSpec::Gd],
        datasets: vec![DatasetKind::SyntheticLinreg],
        workers: vec![4, 6],
        seeds: vec![1],
        target: 1e-2,
        max_iters: 3_000,
        record_stride: 1,
    };
    let serial = SweepRunner::new(1).run(&spec).unwrap();
    let parallel = SweepRunner::new(3).run(&spec).unwrap();
    assert_eq!(serial.cells.len(), 4);
    assert_eq!(parallel.cells.len(), 4);
    for (a, b) in serial.cells.iter().zip(&parallel.cells) {
        assert_eq!(a.key, b.key);
        assert!(
            a.trace.same_path(&b.trace),
            "cell {} differs between 1 and 3 threads",
            a.key.id()
        );
    }
}

#[test]
fn sweep_report_carries_the_grid() {
    let spec = SweepSpec {
        algos: vec![AlgoSpec::Gadmm { rho: 5.0, fault: 0.0, threads: 1 }],
        datasets: vec![DatasetKind::SyntheticLinreg],
        workers: vec![4],
        seeds: vec![1],
        target: 1e-2,
        max_iters: 2_000,
        record_stride: 5,
    };
    let out = SweepRunner::new(2).run(&spec).unwrap();
    let report = out.report(&spec);
    assert_eq!(
        report.path("spec.algos").unwrap().as_arr().unwrap()[0].as_str(),
        Some("gadmm:rho=5")
    );
    assert_eq!(report.path("cells").unwrap().as_arr().unwrap().len(), 1);
}

#[test]
fn sinks_stream_exactly_the_recorded_trace() {
    let problem = small_problem(4, 3);
    let opts = RunOptions::with_target(1e-4, 2_000);
    let mut csv = CsvSink::new(Vec::new());
    let mut mem = MemorySink::new();
    let trace = {
        let mut engine = AlgoSpec::Gadmm { rho: 3.0, fault: 0.0, threads: 1 }.build(&problem, 1);
        let mut sinks: Vec<&mut dyn TraceSink> = vec![&mut csv, &mut mem];
        optim::run_with_sinks(&mut *engine, &problem, &UnitCosts, &opts, &mut sinks)
    };
    assert_eq!(mem.records.len(), trace.records.len());
    assert_eq!(mem.algorithm, trace.algorithm);
    let mut direct = Vec::new();
    trace.write_csv(&mut direct).unwrap();
    assert_eq!(csv.into_inner(), direct, "streamed CSV must match post-hoc CSV byte-for-byte");
}

#[test]
fn coordinator_accepts_gadmm_specs_and_rejects_others() {
    let problem = small_problem(4, 4);
    let opts = RunOptions::with_target(1e-4, 3_000);
    fn solvers(p: &Problem) -> Vec<Box<dyn LocalSolver + Send + '_>> {
        (0..p.num_workers())
            .map(|w| Box::new(NativeSolver::new(&*p.losses[w])) as Box<dyn LocalSolver + Send + '_>)
            .collect()
    }

    // Spec-driven distributed GADMM matches the sequential spec-built engine.
    let result = coordinator::train_spec(
        &problem,
        solvers(&problem),
        &AlgoSpec::Gadmm { rho: 2.0, fault: 0.0, threads: 1 },
        1,
        Chain::sequential(4),
        &UnitCosts,
        &opts,
    )
    .unwrap();
    let seq = optim::run(
        &mut *AlgoSpec::Gadmm { rho: 2.0, fault: 0.0, threads: 1 }.build(&problem, 1),
        &problem,
        &UnitCosts,
        &opts,
    );
    assert_eq!(result.trace.iters_to_target(), seq.iters_to_target());

    // Centralized baselines have no head/tail dataflow to distribute.
    let err = match coordinator::train_spec(
        &problem,
        solvers(&problem),
        &AlgoSpec::Gd,
        1,
        Chain::sequential(4),
        &UnitCosts,
        &opts,
    ) {
        Ok(_) => panic!("non-chain specs must be rejected"),
        Err(e) => e,
    };
    assert!(err.contains("GADMM/Q-GADMM"), "{err}");
}
