//! Allocation-freedom regression test for the steady-state hot path.
//!
//! A counting `#[global_allocator]` wraps the system allocator; after a
//! warmup that primes every lazily-built structure (the linreg Cholesky
//! factor cache, the per-link `MsgBuf`s, the phase scratch), ten further
//! serial GADMM/linreg iterations must perform **zero** heap
//! allocations — the tentpole claim of
//! `docs/adr/008-flat-arena-and-alloc-free-hot-path.md`, pinned here so
//! it can't silently regress.
//!
//! This file is its own test binary (`[[test]] name = "alloc_free"`) and
//! deliberately holds a single `#[test]`: a process-global counter can't
//! distinguish concurrent test threads, and the default harness runs
//! tests in parallel. The engine is driven through `step()` directly —
//! the run driver's trace recording and objective evaluation allocate by
//! design and are outside the steady-state claim.

use gadmm::comm::Meter;
use gadmm::data::synthetic;
use gadmm::model::Problem;
use gadmm::optim::{Engine, Gadmm};
use gadmm::topology::UnitCosts;
use gadmm::util::rng::Pcg64;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

/// System allocator with an allocation-event counter. Frees are not
/// counted — the claim is "no allocations", and a free without a
/// matching allocation is impossible anyway.
struct CountingAlloc;

static ALLOCATIONS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn steady_state_serial_gadmm_linreg_iteration_is_allocation_free() {
    let ds = synthetic::linreg(120, 8, &mut Pcg64::seeded(1));
    let problem = Problem::from_dataset(&ds, 6);
    let mut engine = Gadmm::new(&problem, 5.0);
    let costs = UnitCosts;
    let mut meter = Meter::new(&costs);
    meter.set_payload_bits(64.0 * 8.0);

    // Warmup: first iterations build the per-c Cholesky factors and size
    // the reusable wire buffers. Construction *should* allocate — a zero
    // count here would mean the counter isn't installed.
    for k in 0..50 {
        engine.step(k, &mut meter);
    }
    assert!(
        ALLOCATIONS.load(Ordering::SeqCst) > 0,
        "counting allocator saw no allocations at all — wrapper not installed?"
    );

    let before = ALLOCATIONS.load(Ordering::SeqCst);
    for k in 50..60 {
        engine.step(k, &mut meter);
    }
    let after = ALLOCATIONS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "steady-state GADMM/linreg iterations allocated {} time(s) in 10 steps — \
         the allocation-free hot path regressed",
        after - before
    );

    // The ten audited steps did real work: the objective kept improving
    // toward f* (guards against a degenerate no-op step "passing").
    assert!(engine.objective().is_finite());
}
