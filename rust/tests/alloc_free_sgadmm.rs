//! Allocation-freedom regression test for the data-axis hot paths added
//! by ADR-010: the S-GADMM steady-state iteration (SVRG inner loop,
//! seeded minibatch draws, periodic anchor refresh) and the out-of-core
//! `FileBackedSource::read_chunk` loop through one reusable `ChunkBuf`.
//!
//! Same shape as `alloc_free.rs`: a counting `#[global_allocator]`, a
//! warmup that primes every lazily-built structure, then an audited
//! window that must allocate **zero** times. The audited S-GADMM window
//! spans 10 outer iterations = 40 prox calls (N=4), which crosses several
//! `ANCHOR_REFRESH` boundaries — the refresh (coefficient re-cache +
//! `Xᵀ·coeff` into the preallocated workspace) is part of the claim, not
//! an exemption. Own test binary with a single `#[test]`: the process-
//! global counter can't distinguish concurrent test threads.

use gadmm::comm::Meter;
use gadmm::data::{synthetic, ChunkBuf, FileBackedSource, InMemorySource, SampleSource};
use gadmm::model::Problem;
use gadmm::optim::{Engine, Sgadmm};
use gadmm::topology::UnitCosts;
use gadmm::util::rng::Pcg64;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

struct CountingAlloc;

static ALLOCATIONS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn steady_state_sgadmm_and_streaming_reads_are_allocation_free() {
    // --- S-GADMM: non-degenerate stochastic prox (batch 16 < m_s 60). ---
    let ds = synthetic::linreg(240, 8, &mut Pcg64::seeded(1));
    let problem = Problem::from_dataset(&ds, 4);
    let mut engine = Sgadmm::new(&problem, 5.0, 16, 2.0, 7).unwrap();
    let costs = UnitCosts;
    let mut meter = Meter::new(&costs);
    meter.set_payload_bits(64.0 * 8.0);

    // Warmup: sizes the wire buffers and runs the first anchor refreshes.
    for k in 0..50 {
        engine.step(k, &mut meter);
    }
    assert!(
        ALLOCATIONS.load(Ordering::SeqCst) > 0,
        "counting allocator saw no allocations at all — wrapper not installed?"
    );

    let before = ALLOCATIONS.load(Ordering::SeqCst);
    for k in 50..60 {
        engine.step(k, &mut meter);
    }
    let after = ALLOCATIONS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "steady-state S-GADMM iterations allocated {} time(s) in 10 steps — \
         the stochastic prox workspace discipline regressed",
        after - before
    );
    assert!(engine.objective().is_finite());

    // --- FileBackedSource: chunked reads through one reusable buffer. ---
    let path = std::env::temp_dir()
        .join(format!("gadmm-allocfree-sgadmm-{}.bin", std::process::id()));
    let src = InMemorySource::new(ds);
    let fb = FileBackedSource::create(&path, &src, 32).unwrap();
    let mut buf = ChunkBuf::new(fb.dim(), 32);
    // Warmup read primes nothing lazily (the buffer is fully sized at
    // construction) but keeps the two claims symmetric.
    fb.read_chunk(0, 32, &mut buf).unwrap();

    let before = ALLOCATIONS.load(Ordering::SeqCst);
    let mut checksum = 0.0;
    for _ in 0..5 {
        let mut lo = 0;
        while lo < fb.num_samples() {
            let hi = (lo + buf.capacity_rows()).min(fb.num_samples());
            fb.read_chunk(lo, hi, &mut buf).unwrap();
            checksum += buf.target(0);
            lo = hi;
        }
    }
    let after = ALLOCATIONS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "steady-state file-backed chunk reads allocated {} time(s) — \
         the reusable ChunkBuf discipline regressed",
        after - before
    );
    assert!(checksum.is_finite());
    std::fs::remove_file(&path).ok();
}
