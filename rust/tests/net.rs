//! End-to-end pins for the TCP transport subsystem (`gadmm serve`).
//!
//! The headline tests spawn **real OS worker processes** (the `gadmm`
//! binary itself, via `CARGO_BIN_EXE_gadmm`) against an in-process lead on
//! an ephemeral localhost port and assert bit-identity against the channel
//! coordinator: same deterministic trace path (`Trace::same_path`) and
//! bitwise-equal final models, for all six distributable engines, with and
//! without fault injection. This is the repo's strongest reproducibility
//! claim — the network is not allowed to perturb a single bit — argued in
//! `docs/adr/007-transport-seam.md`.

use gadmm::config::DatasetKind;
use gadmm::experiments::bench::BenchSpec;
use gadmm::experiments::netbench;
use gadmm::net::frame::{read_frame, write_frame, Frame, Setup};
use gadmm::net::lead::{run_lead_on, ServeConfig};
use gadmm::net::worker::run_remote_worker;
use gadmm::optim::RunOptions;
use gadmm::session::{AlgoSpec, DEFAULT_CENSOR_MU, DEFAULT_CENSOR_TAU};
use std::net::{TcpListener, TcpStream};
use std::path::Path;

/// The `gadmm` binary the worker fleet is spawned from.
const EXE: &str = env!("CARGO_BIN_EXE_gadmm");

/// A seconds-long grid: small N, loose target — enough iterations to
/// exercise both phases, quantizer state, censor thresholds, and the
/// barrier protocol many hundreds of times.
fn tiny_grid() -> BenchSpec {
    BenchSpec {
        dataset: DatasetKind::SyntheticLinreg,
        workers: 4,
        rho: 5.0,
        bits: 8,
        tau: DEFAULT_CENSOR_TAU,
        mu: DEFAULT_CENSOR_MU,
        target: 1e-2,
        max_iters: 5_000,
        record_stride: 1,
    }
}

#[test]
fn six_engines_are_bit_identical_over_localhost() {
    let grid = tiny_grid();
    let roster = netbench::net_roster(grid.rho, grid.bits, grid.tau, grid.mu);
    let out = netbench::run_with(&grid, &roster, true, 1, Path::new(EXE)).unwrap();
    assert_eq!(out.rows.len(), 6);
    for row in &out.rows {
        assert!(
            row.identical(),
            "{} diverged across the network",
            row.spec.spec_string()
        );
        assert!(row.wire_bytes > 0, "{} reported no wire traffic", row.spec.spec_string());
        // The runs did real work, not a 0-iteration no-op agreement.
        assert!(!row.net.trace.records.is_empty());
    }
    assert!(out.all_identical());
    let text = out.report.to_string_pretty();
    assert!(text.contains("bench_net"), "report must carry the experiment tag");
}

#[test]
fn fault_injected_runs_cross_the_network_bit_identically() {
    // fault=p drops slots via the seeded schedule *inside* the link
    // policies; the explicit Skip frames must carry the censoring across
    // the wire so the faulted nets replay the faulted channel runs exactly.
    let grid = tiny_grid();
    let roster: Vec<AlgoSpec> = netbench::net_roster(grid.rho, grid.bits, grid.tau, grid.mu)
        .into_iter()
        .map(|s| s.with_fault(0.1))
        .collect();
    let out = netbench::run_with(&grid, &roster, true, 1, Path::new(EXE)).unwrap();
    assert_eq!(out.rows.len(), 6);
    for row in &out.rows {
        assert!(
            row.identical(),
            "{} diverged across the network under fault injection",
            row.spec.spec_string()
        );
    }
}

#[test]
fn layer_scheduled_spec_is_bit_identical_over_localhost() {
    // L-FGADMM crosses the transport seam end to end: the Setup frame
    // carries the layer plan, every spawned worker rebuilds the same
    // k-pure LayerScheduled links from it, and the scheduled layers travel
    // as layered frames (a stale layer is simply absent) — so a real
    // lead + 4-worker-process deployment must replay the channel
    // coordinator bit for bit, including the period-2 layer's idle rounds.
    let grid = tiny_grid();
    let roster = [AlgoSpec::parse("lfgadmm:rho=5,layers=30-20,periods=1-2").unwrap()];
    let out = netbench::run_with(&grid, &roster, true, 1, Path::new(EXE)).unwrap();
    assert_eq!(out.rows.len(), 1);
    let row = &out.rows[0];
    assert!(
        row.identical(),
        "{} diverged across the network",
        row.spec.spec_string()
    );
    assert!(row.wire_bytes > 0, "no wire traffic recorded");
    assert!(!row.net.trace.records.is_empty(), "net run did no work");
}

#[test]
fn stochastic_spec_is_bit_identical_over_localhost() {
    // S-GADMM crosses the transport seam end to end: the Setup frame
    // carries (spec, seed), every spawned worker process rebuilds its own
    // seeded StochasticProx through coordinator::spec_solver, and the
    // minibatch draws — a pure function of (seed, worker, draw) — replay
    // the channel coordinator's exactly, so a real lead + 4-process
    // deployment must take the identical deterministic path.
    let grid = tiny_grid();
    let roster = [AlgoSpec::parse("sgadmm:rho=5,batch=64,epochs=2").unwrap()];
    let out = netbench::run_with(&grid, &roster, true, 1, Path::new(EXE)).unwrap();
    assert_eq!(out.rows.len(), 1);
    let row = &out.rows[0];
    assert!(
        row.identical(),
        "{} diverged across the network",
        row.spec.spec_string()
    );
    assert!(row.wire_bytes > 0, "no wire traffic recorded");
    assert!(!row.net.trace.records.is_empty(), "net run did no work");
    assert!(
        row.net.trace.algorithm.starts_with("S-GADMM-dist("),
        "unexpected engine label {}",
        row.net.trace.algorithm
    );
}

#[test]
fn setup_frames_roundtrip_every_distributable_spec() {
    let lfgadmm = AlgoSpec::parse("lfgadmm:rho=5,layers=30-20,periods=1-2").unwrap();
    let sgadmm = AlgoSpec::parse("sgadmm:rho=5,batch=64,epochs=2").unwrap();
    for spec in netbench::net_roster(5.0, 8, DEFAULT_CENSOR_TAU, DEFAULT_CENSOR_MU)
        .into_iter()
        .chain([lfgadmm, sgadmm])
    {
        for spec in [spec, spec.with_fault(0.1)] {
            let setup = Setup {
                spec,
                dataset: "synthetic-linreg".to_string(),
                seed: 7,
                workers: 4,
                timeout_ms: 1234,
                heads: vec![0, 2],
                tails: vec![1, 3],
                edges: vec![(0, 1), (1, 2), (2, 3)],
                peers: (0..4).map(|r| format!("127.0.0.1:500{r}")).collect(),
            };
            let frame = Frame::SetupFrame(setup);
            let bytes = frame.encode();
            let back = read_frame(&mut bytes.as_slice()).unwrap();
            assert_eq!(back, frame, "{} did not survive the wire", spec.spec_string());
        }
    }
}

#[test]
fn lead_names_the_rank_that_disconnects_mid_run() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();

    // Rank 0: a real worker, with a short mesh timeout so its dead
    // neighbour costs it a second instead of the 30 s default.
    let w0_addr = addr.clone();
    let w0 = std::thread::spawn(move || run_remote_worker(&w0_addr, 0, Some(1000)));

    // Rank 1: handshakes correctly, reads the first Iterate, then silently
    // dies — control closed without a report, mesh left dangling open (the
    // nastiest failure mode: a peer that stops talking without hanging up).
    let w1_addr = addr.clone();
    let w1 = std::thread::spawn(move || {
        let mesh_listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let mesh_addr = mesh_listener.local_addr().unwrap().to_string();
        let mut control = TcpStream::connect(&w1_addr).unwrap();
        write_frame(&mut control, &Frame::Hello { rank: 1, addr: mesh_addr }).unwrap();
        match read_frame(&mut control).unwrap() {
            Frame::SetupFrame(s) => assert_eq!(s.workers, 2),
            other => panic!("expected setup, got {other:?}"),
        }
        // Lower rank dials higher: accept rank 0's mesh stream.
        let (mut mesh, _) = mesh_listener.accept().unwrap();
        match read_frame(&mut mesh).unwrap() {
            Frame::Peer { rank: 0 } => {}
            other => panic!("expected peer 0, got {other:?}"),
        }
        write_frame(&mut control, &Frame::Ready { rank: 1 }).unwrap();
        match read_frame(&mut control).unwrap() {
            Frame::Iterate => {}
            other => panic!("expected iterate, got {other:?}"),
        }
        drop(control);
        // Keep the mesh socket open while the lead notices the dead
        // control stream, so the failure is detected *there*, by rank.
        std::thread::sleep(std::time::Duration::from_secs(3));
        drop(mesh);
    });

    let cfg = ServeConfig {
        workers: 2,
        spec: AlgoSpec::Gadmm { rho: 5.0, fault: 0.0, threads: 1 },
        dataset: DatasetKind::SyntheticLinreg,
        seed: 1,
        opts: RunOptions::with_target(1e-2, 200),
        timeout_ms: 10_000,
        area_side: 10.0,
    };
    let err = run_lead_on(listener, &cfg).unwrap_err();
    assert!(
        err.contains("worker 1"),
        "lead must name the rank that went away, got: {err}"
    );
    // No hang: both worker threads wind down (rank 0 exits on the lead's
    // shutdown broadcast or its own transport error — either is orderly).
    let _ = w0.join().unwrap();
    w1.join().unwrap();
}
