//! Crash/rejoin chaos pins on the paper's linreg configuration: a worker
//! that goes dark for a window of iterations (every broadcast dropped by
//! the seeded [`FaultSchedule`]) must leave the rest of the fleet running
//! on its cached public view, rejoin seamlessly, and still converge to the
//! paper's 1e-4 target — on the sequential engines, on the distributed
//! coordinator (bit-for-bit against the sequential path), and through
//! D-GADMM's re-chaining, whose slot re-map is the recovery story
//! (docs/adr/006-fault-injection.md): duals and fault wrappers travel with
//! the physical worker, so a crash window survives any chain rebuild.

use gadmm::comm::{dense_links, faulty_links, FaultSchedule};
use gadmm::coordinator;
use gadmm::data::synthetic;
use gadmm::linalg::vector as vec_ops;
use gadmm::model::Problem;
use gadmm::optim::{run, Dgadmm, Gadmm, RechainMode, RunOptions};
use gadmm::runtime::{LocalSolver, NativeSolver};
use gadmm::topology::chain::Chain;
use gadmm::topology::graph::BipartiteGraph;
use gadmm::topology::UnitCosts;
use gadmm::util::rng::Pcg64;

/// The paper's linreg configuration (same as the exec-backend pins).
fn paper_linreg() -> Problem {
    let ds = synthetic::linreg(120, 8, &mut Pcg64::seeded(1));
    Problem::from_dataset(&ds, 6)
}

fn native_solvers(p: &Problem) -> Vec<Box<dyn LocalSolver + Send + '_>> {
    (0..p.num_workers())
        .map(|w| Box::new(NativeSolver::new(&*p.losses[w])) as Box<dyn LocalSolver + Send + '_>)
        .collect()
}

/// Worker 2 crashes at iteration 10 and rejoins at 25 (15 lost slots).
fn crash_schedule() -> FaultSchedule {
    FaultSchedule::new(7, 0.0).with_crash(2, 10, 25)
}

#[test]
fn crashed_worker_rejoins_and_sequential_gadmm_converges() {
    let p = paper_linreg();
    let opts = RunOptions::with_target(1e-4, 10_000);
    let costs = UnitCosts;
    let mut g = Gadmm::new(&p, 5.0);
    g.install_faults(&crash_schedule());
    let trace = run(&mut g, &p, &costs, &opts);
    assert!(
        trace.iters_to_target().is_some(),
        "GADMM did not recover from the crash window: final err {}",
        trace.final_error()
    );
    // The crash really bit: exactly the 15 windowed slots are missing from
    // the unit TC (dense links never censor on their own).
    let last = trace.records.last().unwrap();
    assert!(last.iter >= 25, "converged before the rejoin — the window is vacuous");
    assert_eq!(last.tc_unit, (last.iter * 6 - 15) as f64, "TC deficit ≠ crash window");
}

#[test]
fn crash_chaos_run_is_bit_identical_across_execution_paths() {
    // The same crash schedule through coordinator::train_links — the chaos
    // harness's custom-wire entry point — must reproduce the sequential
    // faulted engine record by record: same convergence point, same slot
    // and bit accounting, bitwise-equal consensus violation. (Only the
    // monitoring objective may differ by float-summation order.)
    let p = paper_linreg();
    let opts = RunOptions::with_target(1e-4, 10_000);
    let costs = UnitCosts;

    let mut seq = Gadmm::new(&p, 5.0);
    seq.install_faults(&crash_schedule());
    let seq_trace = run(&mut seq, &p, &costs, &opts);

    let links = faulty_links(dense_links(p.dim, 6), &crash_schedule());
    let dist = coordinator::train_links(
        &p,
        native_solvers(&p),
        5.0,
        BipartiteGraph::from_chain(&Chain::sequential(6)),
        &costs,
        &opts,
        links,
        "GADMM-chaos(rho=5,crash=2@10..25)".into(),
    );

    assert_eq!(dist.trace.iters_to_target(), seq_trace.iters_to_target());
    assert_eq!(dist.trace.records.len(), seq_trace.records.len());
    for (a, b) in dist.trace.records.iter().zip(&seq_trace.records) {
        assert!(
            (a.obj_err - b.obj_err).abs() <= 1e-9 * (1.0 + b.obj_err),
            "iter {}: {} vs {}",
            a.iter,
            a.obj_err,
            b.obj_err
        );
        assert_eq!(a.tc_unit, b.tc_unit, "iter {}: TC mismatch", a.iter);
        assert_eq!(a.bits, b.bits, "iter {}: bit accounting mismatch", a.iter);
        assert_eq!(a.acv, b.acv, "iter {}: ACV mismatch", a.iter);
    }
    for (a, b) in dist.thetas.iter().zip(seq.thetas()) {
        assert!(vec_ops::dist2(a, b) < 1e-12, "final model mismatch");
    }
}

#[test]
fn crashed_dgadmm_worker_recovers_through_rechaining() {
    // The crash-as-rechain story: D-GADMM rebuilds its logical chain every
    // τ iterations, and the fault wrappers are indexed by *physical*
    // worker, so the crash window keeps tracking worker 3 through every
    // re-map — and the run still converges to the paper's target. τ=1
    // (free mode) re-chains on every iteration, the strongest exercise of
    // the slot re-map.
    let p = paper_linreg();
    let opts = RunOptions::with_target(1e-4, 20_000);
    let costs = UnitCosts;
    let mut e = Dgadmm::new(&p, 5.0, 1, RechainMode::Free, &costs, 3);
    e.install_faults(&FaultSchedule::new(3, 0.0).with_crash(3, 15, 45));
    let trace = run(&mut e, &p, &costs, &opts);
    assert!(
        trace.iters_to_target().is_some(),
        "D-GADMM did not recover from the crash window: final err {}",
        trace.final_error()
    );
    let last = trace.records.last().unwrap();
    assert!(last.iter >= 45, "converged before the rejoin — the window is vacuous");
    // Free-mode re-chaining charges nothing, so the only TC deficit is the
    // 30-slot crash window — proof the window followed the worker across
    // every chain rebuild instead of smearing over chain positions.
    assert_eq!(last.tc_unit, (last.iter * 6 - 30) as f64, "TC deficit ≠ crash window");
}
