//! Cross-module integration: every algorithm on shared problems, TC
//! accounting arithmetic, experiment drivers, and config plumbing.

use gadmm::config::{DatasetKind, RunConfig};
use gadmm::data::synthetic;
use gadmm::model::Problem;
use gadmm::optim::{
    run, Admm, Dgadmm, Dgd, DualAvg, Gadmm, Gd, Iag, IagOrder, Lag, LagVariant, RechainMode,
    RunOptions,
};
use gadmm::topology::{EnergyCostModel, Placement, UnitCosts};
use gadmm::util::rng::Pcg64;

fn linreg_problem(n: usize) -> Problem {
    let ds = synthetic::linreg(240, 10, &mut Pcg64::seeded(11));
    Problem::from_dataset(&ds, n)
}

fn logreg_problem(n: usize) -> Problem {
    let ds = synthetic::logreg(240, 8, &mut Pcg64::seeded(12));
    Problem::from_dataset(&ds, n)
}

#[test]
fn every_algorithm_converges_on_linreg() {
    let p = linreg_problem(6);
    let costs = UnitCosts;
    let opts = RunOptions::with_target(1e-4, 300_000);
    let n = p.num_workers() as f64;

    let gadmm = run(&mut Gadmm::new(&p, 3.0), &p, &costs, &opts);
    let admm = run(&mut Admm::new(&p, 3.0), &p, &costs, &opts);
    let gd = run(&mut Gd::new(&p), &p, &costs, &opts);
    let lag_wk = run(&mut Lag::new(&p, LagVariant::Wk), &p, &costs, &opts);
    let lag_ps = run(&mut Lag::new(&p, LagVariant::Ps), &p, &costs, &opts);
    let iag = run(&mut Iag::new(&p, IagOrder::Cyclic, 1), &p, &costs, &opts);
    let riag = run(&mut Iag::new(&p, IagOrder::RandomWeighted, 1), &p, &costs, &opts);

    for t in [&gadmm, &admm, &gd, &lag_wk, &lag_ps, &iag, &riag] {
        assert!(
            t.iters_to_target().is_some(),
            "{} did not converge (final {:.3e})",
            t.algorithm,
            t.final_error()
        );
    }
    // TC structure: GADMM pays N per iteration, GD pays N+1, IAG pays 2.
    let k = gadmm.iters_to_target().unwrap() as f64;
    assert_eq!(gadmm.tc_to_target(), Some(k * n));
    let kg = gd.iters_to_target().unwrap() as f64;
    assert_eq!(gd.tc_to_target(), Some(kg * (n + 1.0)));
    let ki = iag.iters_to_target().unwrap() as f64;
    assert_eq!(iag.tc_to_target(), Some(ki * 2.0));
    // LAG-WK undercuts GD's TC even on this small instance.
    assert!(lag_wk.tc_to_target().unwrap() < gd.tc_to_target().unwrap());
}

#[test]
fn gadmm_beats_gd_by_orders_of_magnitude_at_paper_conditioning() {
    // The paper's headline (Fig. 2 / Table 1) needs the ill-conditioned
    // design; on a mid-size instance with κ = 3000 GADMM's ADMM-type rate
    // (~√κ) crushes GD's κ-limited rate.
    let ds = synthetic::linreg_cond(480, 24, 3000.0, &mut Pcg64::seeded(21));
    let p = Problem::from_dataset(&ds, 12);
    let costs = UnitCosts;
    let opts = RunOptions::with_target(1e-4, 300_000);
    let gadmm = run(&mut Gadmm::new(&p, 3.0), &p, &costs, &opts);
    let gd = run(&mut Gd::new(&p), &p, &costs, &opts);
    let k = gadmm.iters_to_target().expect("GADMM converges") as f64;
    let kg = gd.iters_to_target().expect("GD converges") as f64;
    assert!(k * 5.0 < kg, "GADMM {k} not ≪ GD {kg}");
    assert!(
        gadmm.tc_to_target().unwrap() < gd.tc_to_target().unwrap(),
        "GADMM TC not below GD TC"
    );
}

#[test]
fn every_algorithm_converges_or_progresses_on_logreg() {
    let p = logreg_problem(4);
    let costs = UnitCosts;
    let opts = RunOptions::with_target(1e-4, 300_000);
    for (name, trace) in [
        ("gadmm", run(&mut Gadmm::new(&p, 0.3), &p, &costs, &opts)),
        ("admm", run(&mut Admm::new(&p, 0.3), &p, &costs, &opts)),
        ("gd", run(&mut Gd::new(&p), &p, &costs, &opts)),
        ("lag-wk", run(&mut Lag::new(&p, LagVariant::Wk), &p, &costs, &opts)),
    ] {
        assert!(
            trace.iters_to_target().is_some(),
            "{name} did not converge (final {:.3e})",
            trace.final_error()
        );
    }
    // The diminishing-step decentralized baselines only need to make
    // substantial progress within the budget (they are O(1/√k)).
    let dgd = run(&mut Dgd::new(&p), &p, &costs, &RunOptions::with_target(1e-4, 20_000));
    let da = run(&mut DualAvg::new(&p), &p, &costs, &RunOptions::with_target(1e-4, 20_000));
    for (name, t) in [("dgd", dgd), ("dualavg", da)] {
        let drop = t.records.first().unwrap().obj_err / t.final_error().max(1e-300);
        assert!(
            t.iters_to_target().is_some() || drop > 10.0,
            "{name} made no progress ({:.3e} → {:.3e})",
            t.records.first().unwrap().obj_err,
            t.final_error()
        );
    }
}

#[test]
fn dgadmm_tracks_gadmm_on_both_tasks() {
    let costs = UnitCosts;
    for (p, rho) in [(linreg_problem(6), 3.0), (logreg_problem(4), 0.3)] {
        let opts = RunOptions::with_target(1e-4, 300_000);
        let static_t = run(&mut Gadmm::new(&p, rho), &p, &costs, &opts);
        let mut dyn_e = Dgadmm::new(&p, rho, 15, RechainMode::Free, &costs, 5);
        let dyn_t = run(&mut dyn_e, &p, &costs, &opts);
        let (sk, dk) = (
            static_t.iters_to_target().expect("static converges"),
            dyn_t.iters_to_target().expect("dynamic converges"),
        );
        // D-GADMM must stay within a small factor of static GADMM.
        assert!(dk <= sk * 4, "D-GADMM {dk} ≥ 4× GADMM {sk} ({})", p.name);
    }
}

#[test]
fn energy_accounting_consistent_between_runs() {
    // Running the same engine under unit costs and energy costs must give
    // identical iterate paths (costs are observational only).
    let p = linreg_problem(6);
    let opts = RunOptions::with_target(1e-4, 100_000);
    let unit_trace = run(&mut Gadmm::new(&p, 3.0), &p, &UnitCosts, &opts);
    let mut rng = Pcg64::seeded(3);
    let placement = Placement::random(6, 10.0, &mut rng);
    let energy = EnergyCostModel::new(&placement, placement.central_worker());
    let energy_trace = run(&mut Gadmm::new(&p, 3.0), &p, &energy, &opts);
    assert_eq!(unit_trace.iters_to_target(), energy_trace.iters_to_target());
    for (a, b) in unit_trace.records.iter().zip(&energy_trace.records) {
        assert_eq!(a.obj_err, b.obj_err);
        assert_eq!(a.tc_unit, b.tc_unit);
    }
    assert!(energy_trace.energy_to_target().unwrap() > 0.0);
}

#[test]
fn config_round_trip_drives_dataset_construction() {
    let cfg = RunConfig {
        dataset: DatasetKind::Bodyfat,
        workers: 4,
        rho: 0.1,
        target: 1e-3,
        max_iters: 30_000,
        seed: 2,
        area_side: 10.0,
        tau: 5,
        quant_bits: None,
        quant_seed: None,
    };
    let ds = cfg.dataset.build(cfg.seed);
    let p = Problem::from_dataset(&ds, cfg.workers);
    let t = run(
        &mut Gadmm::new(&p, cfg.rho),
        &p,
        &UnitCosts,
        &RunOptions::with_target(cfg.target, cfg.max_iters),
    );
    assert!(t.iters_to_target().is_some(), "final {:.3e}", t.final_error());
}

#[test]
fn rho_sensitivity_depends_on_data_correlation() {
    // The paper's §7 point: the optimal ρ is data-dependent, driven by how
    // close local optima sit to the global one. On our correlated real
    // surrogate (local ≈ global optimum) strong coupling converges in a
    // handful of iterations while weak coupling crawls; on the synthetic
    // independent/ill-conditioned data the optimum is interior (ρ* ≈ 3) —
    // see EXPERIMENTS.md for the measured landscape and the direction
    // difference vs the paper's presentation.
    let ds = gadmm::data::real::bodyfat(1);
    let p = Problem::from_dataset(&ds, 10);
    let opts = RunOptions::with_target(1e-4, 200_000);
    let weak = run(&mut Gadmm::new(&p, 0.1), &p, &UnitCosts, &opts);
    let strong = run(&mut Gadmm::new(&p, 7.0), &p, &UnitCosts, &opts);
    let kw = weak.iters_to_target().expect("weak rho converges");
    let ks = strong.iters_to_target().expect("strong rho converges");
    assert!(
        ks * 10 < kw,
        "correlated data should favour strong coupling: rho=7 took {ks}, rho=0.1 took {kw}"
    );
}

#[test]
fn ggadmm_converges_on_a_24_worker_random_geometric_graph() {
    // The acceptance-scale GGADMM run: N=24 workers on a 2-colored random
    // geometric graph over the paper's 10×10 m² area, to the paper's 1e-4
    // objective-error target (the `gadmm graph` driver's RGG row).
    use gadmm::optim::Ggadmm;
    use gadmm::topology::graph::GraphKind;

    let ds = synthetic::linreg(480, 12, &mut Pcg64::seeded(21));
    let p = Problem::from_dataset(&ds, 24);
    let placement = Placement::random(24, 10.0, &mut Pcg64::seeded(5));
    let mut e = Ggadmm::with_placement(&p, 5.0, GraphKind::Rgg { radius: 3.5 }, &placement)
        .expect("stitched RGG is always valid");
    assert!(e.graph().len() == 24 && e.graph().num_edges() >= 23);
    let costs = EnergyCostModel::new(&placement, placement.central_worker());
    let trace = run(&mut e, &p, &costs, &RunOptions::with_target(1e-4, 100_000));
    let k = trace.iters_to_target().unwrap_or_else(|| {
        panic!("GGADMM on the N=24 RGG missed 1e-4 (final err {:.3e})", trace.final_error())
    });
    // N broadcast slots per iteration, on any topology.
    assert_eq!(trace.tc_to_target(), Some((k * 24) as f64));
}
