//! Integration: the AOT-compiled JAX+Pallas artifacts, executed from rust
//! via PJRT, must agree with the native backend — and drive the full
//! distributed coordinator to convergence.
//!
//! Skips (with a loud notice) when `artifacts/` hasn't been built; run
//! `make artifacts` first.

use gadmm::coordinator;
use gadmm::data::{partition_even, synthetic, Task};
use gadmm::linalg::vector as vec_ops;
use gadmm::model::Problem;
use gadmm::optim::RunOptions;
use gadmm::runtime::pjrt::PjrtContext;
use gadmm::runtime::service::PjrtService;
use gadmm::runtime::{artifacts_dir, Manifest};
use gadmm::topology::chain::Chain;
use gadmm::topology::UnitCosts;
use gadmm::util::rng::Pcg64;

fn manifest_or_skip() -> Option<Manifest> {
    match Manifest::load(&artifacts_dir()) {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("SKIP pjrt tests: {e}; run `make artifacts`");
            None
        }
    }
}

#[test]
fn linreg_artifact_matches_native_solver() {
    let Some(manifest) = manifest_or_skip() else { return };
    let ds = synthetic::linreg(120, 8, &mut Pcg64::seeded(1));
    let p = Problem::from_dataset(&ds, 6);
    let shards = partition_even(&ds, 6);
    let mut ctx = PjrtContext::new(manifest).expect("pjrt context");
    let mut rng = Pcg64::seeded(2);
    for w in [0usize, 3, 5] {
        let solver = ctx
            .solver_for_shard(
                Task::LinearRegression,
                &shards[w].features,
                &shards[w].targets,
                0.0,
                p.data_weight,
            )
            .expect("solver");
        for c in [1.0, 2.0, 6.0] {
            let q = rng.normal_vec(8);
            let got = solver.prox(&q, c, &vec![0.0; 8]).expect("pjrt prox");
            let want = p.losses[w].prox_argmin(&q, c, &vec![0.0; 8]);
            let err = vec_ops::dist2(&got, &want);
            assert!(err < 1e-6, "worker {w} c={c}: PJRT vs native dist {err}");
        }
    }
}

#[test]
fn logreg_artifact_matches_native_solver() {
    let Some(manifest) = manifest_or_skip() else { return };
    let ds = synthetic::logreg(120, 5, &mut Pcg64::seeded(3));
    let p = Problem::from_dataset(&ds, 4);
    let shards = partition_even(&ds, 4);
    let mut ctx = PjrtContext::new(manifest).expect("pjrt context");
    let mut rng = Pcg64::seeded(4);
    for w in [0usize, 2] {
        let solver = ctx
            .solver_for_shard(
                Task::LogisticRegression,
                &shards[w].features,
                &shards[w].targets,
                p.logreg_mu,
                p.data_weight,
            )
            .expect("solver");
        for c in [0.3, 1.0] {
            let q: Vec<f64> = rng.normal_vec(5).iter().map(|x| 0.2 * x).collect();
            let got = solver.prox(&q, c, &vec![0.0; 5]).expect("pjrt prox");
            let want = p.losses[w].prox_argmin(&q, c, &vec![0.0; 5]);
            let err = vec_ops::dist2(&got, &want);
            assert!(err < 1e-6, "worker {w} c={c}: PJRT vs native dist {err}");
        }
    }
}

#[test]
fn coordinator_converges_on_pjrt_backend() {
    let Some(manifest) = manifest_or_skip() else { return };
    let ds = synthetic::linreg(120, 8, &mut Pcg64::seeded(1));
    let p = Problem::from_dataset(&ds, 6);
    let shards = partition_even(&ds, 6);
    let service = PjrtService::spawn(
        manifest,
        Task::LinearRegression,
        shards,
        0.0,
        p.data_weight,
    )
    .expect("service");
    let opts = RunOptions::with_target(1e-4, 3000);
    let costs = UnitCosts;
    let result =
        coordinator::train(&p, service.solvers(), 3.0, Chain::sequential(6), &costs, &opts);
    assert!(
        result.trace.iters_to_target().is_some(),
        "PJRT-backed coordinator failed to converge: err {}",
        result.trace.final_error()
    );
    assert!(vec_ops::dist2(&result.consensus, &p.theta_star) < 1e-2);
}

#[test]
fn missing_shape_is_reported() {
    let Some(manifest) = manifest_or_skip() else { return };
    let mut ctx = PjrtContext::new(manifest).expect("pjrt context");
    let err = match ctx.executable("linreg_prox", 999, 999) {
        Ok(_) => panic!("expected missing-artifact error"),
        Err(e) => e,
    };
    assert!(format!("{err}").contains("no artifact"), "{err}");
}
