//! Property tests (randomized, via util::prop) for the paper's invariants:
//! chain validity, Lyapunov monotonicity (Theorem 2), tail dual
//! feasibility (eq. 20), primal-residual decay, TC accounting, the
//! Q-GADMM quantizer (roundtrip error bound, stochastic-rounding
//! unbiasedness, range shrinkage, bit-exact accounting), the
//! bipartite-graph generalization (RGG 2-coloring validity, GGADMM's
//! chain degeneracy, star-graph metering closed form), the fault
//! layer (seed-pure schedules with bit-identical chaos replays, rate-0
//! degeneracy to the unfaulted engines, zero-bit dropped slots), the MLP
//! loss (central-difference gradient check, prox stationarity and
//! in-place bitwise twin across random shapes), the L-FGADMM layer
//! schedule (per-layer bits closed form on dense, quantized, and faulted
//! links; censored layered transmit/transmit_into twin), and the
//! out-of-core data layer (file-backed spill as a bitwise oracle of the
//! in-memory source at every chunk size, streaming-standardizer identity,
//! S-GADMM's full-batch degeneracy to plain GADMM, and
//! `Problem::from_source` driving trajectories identical to
//! `Problem::from_dataset`).

use gadmm::comm::{
    layer_censored_dense_links, layer_quant_links, CensorSchedule, Decoder, FaultSchedule, Meter,
    Msg, MsgBuf, QuantizedMsg, StochasticQuantizer, FP64_BITS, RANGE_OVERHEAD_BITS,
};
use gadmm::data::{
    materialize, synthetic, FileBackedSource, InMemorySource, SampleSource, Standardizer,
    SyntheticStream, Task,
};
use gadmm::linalg::{vector as vec_ops, BlockLayout, Matrix};
use gadmm::model::{prox_residual, LocalLoss, MlpLoss, Problem};
use gadmm::optim::{
    run, solver, Cqgadmm, Engine, Gadmm, Ggadmm, GroupAdmmCore, Lfgadmm, Qgadmm, RunOptions,
    Sgadmm,
};
use gadmm::prop_assert;
use gadmm::session::AlgoSpec;
use gadmm::topology::chain::{self, Chain};
use gadmm::topology::graph::{BipartiteGraph, GraphKind};
use gadmm::topology::{EnergyCostModel, Placement, UnitCosts};
use gadmm::util::prop::check;
use gadmm::util::rng::Pcg64;

/// Random even worker count in [4, 20].
fn rand_even_n(rng: &mut Pcg64) -> usize {
    2 * rng.range(2, 11)
}

#[test]
fn prop_appendix_d_chain_is_valid_alternating_hamiltonian() {
    check(
        "appendix-d-chain",
        101,
        60,
        |rng| {
            let n = rand_even_n(rng);
            let placement = Placement::random(n, 10.0, rng);
            let costs = EnergyCostModel::new(&placement, placement.central_worker());
            let heads = chain::draw_heads(n, rng);
            (n, heads.clone(), chain::greedy_chain(n, &heads, &costs))
        },
        |(n, heads, c)| {
            prop_assert!(c.is_valid_permutation(), "not a permutation: {c:?}");
            prop_assert!(c.order[0] == 0, "first position must be worker 0");
            prop_assert!(c.order[*n - 1] == n - 1, "last position must be worker N-1");
            for (p, w) in c.order.iter().enumerate() {
                let is_head = heads.contains(w);
                prop_assert!(
                    is_head == Chain::is_head_position(p),
                    "worker {w} at position {p} violates head/tail alternation"
                );
            }
            Ok(())
        },
    );
}

#[test]
fn prop_gadmm_lyapunov_monotone_nonincreasing() {
    // Theorem 2: V_k (eq. 32) decreases monotonically. Uses the exact λ*
    // from the dual-feasibility telescede at θ* (optim::solver).
    check(
        "lyapunov-monotone",
        202,
        12,
        |rng| {
            let n = 2 * rng.range(2, 5);
            let m = 40 * n;
            let ds = synthetic::linreg(m, 6, rng);
            let rho = rng.uniform(0.5, 6.0);
            (ds, n, rho)
        },
        |(ds, n, rho)| {
            let p = Problem::from_dataset(ds, *n);
            let mut g = Gadmm::new(&p, *rho);
            let order: Vec<usize> = (0..*n).collect();
            let lambda_star = solver::optimal_duals(&p.losses, &order, &p.theta_star);
            let costs = UnitCosts;
            let mut meter = Meter::new(&costs);
            let mut v_prev = g.lyapunov(&p.theta_star, &lambda_star);
            for k in 0..60 {
                g.step(k, &mut meter);
                let v = g.lyapunov(&p.theta_star, &lambda_star);
                prop_assert!(
                    v <= v_prev * (1.0 + 1e-9),
                    "V increased at iteration {k}: {v_prev} → {v} (rho={rho})"
                );
                v_prev = v;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_tail_dual_feasibility_exact() {
    // Eq. 20: after every iteration the tail workers' dual feasibility
    // holds exactly (up to float error), on arbitrary chains.
    check(
        "tail-dual-feasibility",
        303,
        15,
        |rng| {
            let n = rand_even_n(rng);
            let ds = synthetic::linreg(30 * n, 5, rng);
            // Random valid chain with fixed ends.
            let mut middle: Vec<usize> = (1..n - 1).collect();
            rng.shuffle(&mut middle);
            let mut order = vec![0];
            order.extend(middle);
            order.push(n - 1);
            (ds, n, order, rng.uniform(0.5, 5.0))
        },
        |(ds, n, order, rho)| {
            let p = Problem::from_dataset(ds, *n);
            let mut g = Gadmm::with_chain(&p, *rho, Chain { order: order.clone() });
            let costs = UnitCosts;
            let mut meter = Meter::new(&costs);
            for k in 0..10 {
                g.step(k, &mut meter);
                let r = g.tail_dual_residual();
                prop_assert!(r < 1e-6, "tail dual residual {r} at iteration {k}");
            }
            Ok(())
        },
    );
}

#[test]
fn prop_primal_residuals_decay() {
    check(
        "primal-residual-decay",
        404,
        10,
        |rng| {
            let n = 2 * rng.range(2, 5);
            (synthetic::linreg(40 * n, 6, rng), n)
        },
        |(ds, n)| {
            let p = Problem::from_dataset(ds, *n);
            let mut g = Gadmm::new(&p, 3.0);
            let costs = UnitCosts;
            let mut meter = Meter::new(&costs);
            let early: f64 = {
                for k in 0..5 {
                    g.step(k, &mut meter);
                }
                g.primal_residuals().iter().map(|r| vec_ops::norm2(r)).sum()
            };
            for k in 5..300 {
                g.step(k, &mut meter);
            }
            let late: f64 = g.primal_residuals().iter().map(|r| vec_ops::norm2(r)).sum();
            prop_assert!(
                late < early * 0.1 || late < 1e-8,
                "primal residuals did not decay: {early} → {late}"
            );
            Ok(())
        },
    );
}

#[test]
fn prop_tc_accounting_closed_form() {
    // For GADMM under unit costs, TC after k iterations is exactly k·N and
    // rounds are exactly 2k, for any chain.
    check(
        "tc-closed-form",
        505,
        20,
        |rng| {
            let n = rand_even_n(rng);
            (synthetic::linreg(20 * n, 4, rng), n, rng.range(1, 30))
        },
        |(ds, n, iters)| {
            let p = Problem::from_dataset(ds, *n);
            let mut g = Gadmm::new(&p, 2.0);
            let costs = UnitCosts;
            let mut meter = Meter::new(&costs);
            for k in 0..*iters {
                g.step(k, &mut meter);
            }
            prop_assert!(
                meter.tc_unit == (*iters * *n) as f64,
                "TC {} ≠ k·N = {}",
                meter.tc_unit,
                iters * n
            );
            prop_assert!(meter.rounds == 2 * iters, "rounds {} ≠ 2k", meter.rounds);
            Ok(())
        },
    );
}

#[test]
fn prop_energy_tc_scales_with_area() {
    // Free-space d² law: scaling the placement area by s scales every
    // energy cost by s².
    check(
        "energy-area-scaling",
        606,
        30,
        |rng| {
            let n = rand_even_n(rng);
            let base = Placement::random(n, 10.0, rng);
            let scale = rng.uniform(2.0, 10.0);
            (base, scale)
        },
        |(base, scale)| {
            let scaled = Placement {
                side: base.side * scale,
                positions: base
                    .positions
                    .iter()
                    .map(|&(x, y)| (x * scale, y * scale))
                    .collect(),
            };
            let c1 = EnergyCostModel::new(base, 0);
            let c2 = EnergyCostModel::new(&scaled, 0);
            use gadmm::topology::LinkCosts;
            for a in 0..base.len() {
                for b in 0..base.len() {
                    if a == b || base.distance(a, b) < 1e-3 {
                        continue;
                    }
                    let ratio = c2.link(a, b) / c1.link(a, b);
                    prop_assert!(
                        (ratio - scale * scale).abs() < 1e-6 * scale * scale,
                        "link ({a},{b}) ratio {ratio} ≠ s² = {}",
                        scale * scale
                    );
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_quantizer_roundtrip_error_bounded() {
    // Stochastic uniform quantization with 2^b levels over [−R, R] around
    // the anchor moves each coordinate by at most one level step,
    // 2R/(2^b − 1) ≈ (full range)/2^b.
    check(
        "quantizer-roundtrip-bound",
        808,
        80,
        |rng| {
            let d = rng.range(1, 40);
            let bits = rng.range(2, 13) as u32;
            let scale = rng.uniform(0.05, 20.0);
            let x: Vec<f64> = rng.normal_vec(d).iter().map(|v| v * scale).collect();
            (d, bits, x, rng.next_u64())
        },
        |(d, bits, x, seed)| {
            let mut q = StochasticQuantizer::new(*d, *bits, *seed);
            let msg = q.encode(x);
            let rec = q.public_view();
            let step = 2.0 * msg.range / ((1u64 << *bits) - 1) as f64;
            for (j, (xi, ri)) in x.iter().zip(rec).enumerate() {
                prop_assert!(
                    (xi - ri).abs() <= step + 1e-12,
                    "coord {j}: |{xi} − {ri}| exceeds step {step} (b={bits})"
                );
            }
            Ok(())
        },
    );
}

#[test]
fn prop_quantizer_stochastic_rounding_unbiased() {
    // E[decode(encode(x))] = x: averaging reconstructions over many
    // independent rounding seeds (fixed per case, so the test is
    // deterministic) must concentrate around x at the Monte-Carlo rate.
    check(
        "quantizer-unbiased",
        909,
        6,
        |rng| {
            let d = rng.range(2, 10);
            let bits = rng.range(2, 6) as u32;
            (d, bits, rng.normal_vec(d), rng.next_u64())
        },
        |(d, bits, x, seed_base)| {
            let trials = 4000usize;
            let mut mean = vec![0.0; *d];
            let mut range = 0.0;
            for t in 0..trials {
                let mut q = StochasticQuantizer::new(*d, *bits, seed_base.wrapping_add(t as u64));
                let msg = q.encode(x);
                range = msg.range;
                for (m, r) in mean.iter_mut().zip(q.public_view()) {
                    *m += r / trials as f64;
                }
            }
            // Per-coordinate rounding variance is ≤ step²/4; allow 6 sigma.
            let step = 2.0 * range / ((1u64 << *bits) - 1) as f64;
            let tol = 6.0 * step / (2.0 * (trials as f64).sqrt());
            for (j, (mi, xi)) in mean.iter().zip(x).enumerate() {
                prop_assert!(
                    (mi - xi).abs() <= tol,
                    "coord {j}: bias {:.3e} exceeds {tol:.3e} (b={bits})",
                    (mi - xi).abs()
                );
            }
            Ok(())
        },
    );
}

#[test]
fn prop_quantizer_range_shrinks_on_contracting_iterates() {
    // The Q-GADMM premise: when successive models contract geometrically
    // (rate ≤ 1/2) and b ≥ 5, the transmitted range is monotonically
    // non-increasing: with per-step quantization noise ≤ 2R/(2^b−1), the
    // worst-case recursion R_{k+1} ≤ (contraction)·R_k·… stays below R_k
    // exactly when 2/(2^b−1) ≤ 1/8, i.e. b ≥ 5.
    check(
        "quantizer-range-shrinkage",
        1010,
        40,
        |rng| {
            let d = rng.range(2, 16);
            let bits = 5 + rng.range(0, 4) as u32;
            (d, bits, rng.normal_vec(d), rng.next_u64())
        },
        |(d, bits, v, seed)| {
            let mut q = StochasticQuantizer::new(*d, *bits, *seed);
            let mut prev_range = f64::INFINITY;
            for k in 0..40 {
                let x: Vec<f64> = v.iter().map(|&vi| vi * 0.5f64.powi(k)).collect();
                let msg = q.encode(&x);
                prop_assert!(
                    msg.range <= prev_range * (1.0 + 1e-12),
                    "range grew at step {k}: {prev_range} → {} (b={bits})",
                    msg.range
                );
                prev_range = msg.range;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_quantized_decode_is_receiver_consistent() {
    // decode(prev, msg) is a pure function: replaying a message stream
    // from the same anchor always lands on the sender's public view.
    check(
        "quantizer-decode-consistent",
        1111,
        40,
        |rng| {
            let d = rng.range(1, 12);
            let bits = rng.range(1, 9) as u32;
            let stream: Vec<Vec<f64>> = (0..8).map(|_| rng.normal_vec(d)).collect();
            (d, bits, stream, rng.next_u64())
        },
        |(d, bits, stream, seed)| {
            let mut q = StochasticQuantizer::new(*d, *bits, *seed);
            let mut mirror = vec![0.0; *d];
            for x in stream {
                let msg: QuantizedMsg = q.encode(x);
                mirror = msg.decode(&mirror);
                prop_assert!(
                    mirror == q.public_view(),
                    "receiver mirror diverged from sender anchor"
                );
            }
            Ok(())
        },
    );
}

#[test]
fn prop_qgadmm_bit_accounting_closed_form() {
    // Q-GADMM charges exactly N slots of d·b + 64 bits per iteration;
    // dense GADMM charges N slots of 64·d. Both for any chain length.
    check(
        "qgadmm-bits-closed-form",
        1212,
        12,
        |rng| {
            let n = 2 * rng.range(2, 6);
            let d = rng.range(3, 8);
            let bits = rng.range(2, 11) as u32;
            (synthetic::linreg(20 * n, d, rng), n, d, bits, rng.range(1, 12))
        },
        |(ds, n, d, bits, iters)| {
            let p = Problem::from_dataset(ds, *n);
            let costs = UnitCosts;

            let mut qe = Qgadmm::new(&p, 2.0, *bits, 3);
            let mut meter = Meter::new(&costs);
            for k in 0..*iters {
                qe.step(k, &mut meter);
            }
            let per_msg = *d as f64 * *bits as f64 + RANGE_OVERHEAD_BITS;
            let want = (*iters * *n) as f64 * per_msg;
            prop_assert!(meter.bits == want, "Q-GADMM bits {} ≠ {want}", meter.bits);

            let mut ge = Gadmm::new(&p, 2.0);
            let mut gmeter = Meter::new(&costs);
            gmeter.set_payload_bits(64.0 * *d as f64);
            for k in 0..*iters {
                ge.step(k, &mut gmeter);
            }
            let dense_want = (*iters * *n * *d * 64) as f64;
            prop_assert!(
                gmeter.bits == dense_want,
                "GADMM bits {} ≠ {dense_want}",
                gmeter.bits
            );
            prop_assert!(want < dense_want, "quantized payload not smaller");
            Ok(())
        },
    );
}

#[test]
fn prop_censor_threshold_monotone_decreasing() {
    // The censoring threshold τ·μ^k must decay monotonically for any
    // μ ∈ (0,1): strictly while the value stays in the normal f64 range,
    // non-strictly once it underflows toward zero. The incremental
    // construction (thr ← thr·μ) guarantees this by IEEE-754 rounding
    // monotonicity.
    check(
        "censor-threshold-monotone",
        1313,
        60,
        |rng| {
            let tau = rng.uniform(1e-6, 50.0);
            let mu = rng.uniform(0.5, 0.999);
            let steps = rng.range(2, 2000);
            (tau, mu, steps)
        },
        |(tau, mu, steps)| {
            let mut s = CensorSchedule::new(*tau, *mu);
            let mut prev = s.threshold(0);
            prop_assert!(prev == *tau, "threshold(0) = {prev} ≠ tau {tau}");
            for k in 1..*steps {
                let thr = s.threshold(k);
                if prev > 1e-290 {
                    prop_assert!(
                        thr < prev,
                        "threshold failed to strictly decrease at k={k}: {prev} → {thr} \
                         (tau={tau}, mu={mu})"
                    );
                } else {
                    prop_assert!(thr <= prev, "threshold grew at k={k}: {prev} → {thr}");
                }
                prop_assert!(thr >= 0.0, "negative threshold {thr}");
                prev = thr;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_meter_mixed_slot_accounting_closed_form() {
    // Interleaved dense, quantized, and censored slots: bits, unit TC, and
    // transmissions must each equal their closed-form sums, and censored
    // slots must contribute to none of them.
    check(
        "meter-mixed-accounting",
        1414,
        60,
        |rng| {
            let d = rng.range(1, 60);
            let bits = rng.range(1, 13) as u32;
            // Random slot sequence: 0 = dense, 1 = quantized, 2 = censored.
            let slots: Vec<usize> = (0..rng.range(1, 120)).map(|_| rng.range(0, 3)).collect();
            (d, bits, slots)
        },
        |(d, bits, slots)| {
            let costs = UnitCosts;
            let mut m = Meter::new(&costs);
            let dense = 64.0 * *d as f64;
            let quant = *d as f64 * *bits as f64 + 64.0;
            let (mut nd, mut nq, mut ns) = (0usize, 0usize, 0usize);
            for (i, kind) in slots.iter().enumerate() {
                match *kind {
                    0 => {
                        m.neighbor_broadcast_bits(i % 4, &[(i + 1) % 4], dense);
                        nd += 1;
                    }
                    1 => {
                        m.neighbor_broadcast_bits(i % 4, &[(i + 1) % 4, (i + 2) % 4], quant);
                        nq += 1;
                    }
                    _ => {
                        m.censored_slot();
                        ns += 1;
                    }
                }
            }
            let want_bits = nd as f64 * dense + nq as f64 * quant;
            prop_assert!(m.bits == want_bits, "bits {} ≠ {want_bits}", m.bits);
            prop_assert!(
                m.tc_unit == (nd + nq) as f64,
                "tc_unit {} ≠ {}",
                m.tc_unit,
                nd + nq
            );
            prop_assert!(
                m.transmissions == nd + nq,
                "transmissions {} ≠ {}",
                m.transmissions,
                nd + nq
            );
            prop_assert!(m.censored == ns, "censored {} ≠ {ns}", m.censored);
            Ok(())
        },
    );
}

#[test]
fn prop_cqgadmm_tau_zero_degenerates_to_qgadmm() {
    // With τ=0 the censor gate can never fire (‖δ‖ < 0 is impossible), so
    // CQ-GADMM must follow Q-GADMM's exact deterministic path: same
    // private iterates bitwise, same metered bits, for any (bits, seed).
    check(
        "cqgadmm-tau0-degeneracy",
        1515,
        8,
        |rng| {
            let n = 2 * rng.range(2, 4);
            let bits = rng.range(2, 11) as u32;
            (synthetic::linreg(30 * n, 5, rng), n, bits, rng.next_u64(), rng.range(3, 12))
        },
        |(ds, n, bits, seed, iters)| {
            let p = Problem::from_dataset(ds, *n);
            let costs = UnitCosts;
            let mut cq = Cqgadmm::new(&p, 2.0, *bits, 0.0, 0.9, *seed);
            let mut q = Qgadmm::new(&p, 2.0, *bits, *seed);
            let mut m_cq = Meter::new(&costs);
            let mut m_q = Meter::new(&costs);
            for k in 0..*iters {
                cq.step(k, &mut m_cq);
                q.step(k, &mut m_q);
            }
            prop_assert!(m_cq.bits == m_q.bits, "bits {} ≠ {}", m_cq.bits, m_q.bits);
            prop_assert!(m_cq.tc_unit == m_q.tc_unit, "TC differs");
            prop_assert!(m_cq.censored == 0, "τ=0 censored {} slots", m_cq.censored);
            for (a, b) in cq.thetas().iter().zip(q.thetas()) {
                prop_assert!(a == b, "private iterates diverged");
            }
            for (a, b) in cq.hats().iter().zip(q.hats()) {
                prop_assert!(a == b, "public views diverged");
            }
            Ok(())
        },
    );
}

#[test]
fn prop_fault_schedule_is_seed_pure_and_chaos_runs_replay_bit_identically() {
    // The tentpole reproducibility claim: a FaultSchedule is a pure
    // function of (seed, worker, k), so two schedules with the same seed
    // agree on every slot, and a faulted engine run replays its exact
    // trace — bitwise, via Trace::same_path — on a fresh build, at any
    // execution width (threads=2 included), for every group engine.
    check(
        "fault-replay-determinism",
        1616,
        8,
        |rng| {
            let n = 2 * rng.range(2, 5);
            let fault = rng.uniform(0.02, 0.3);
            let rho = 1.0 + rng.range(0, 5) as f64;
            let which = rng.range(0, 6);
            (n, fault, rho, which, rng.next_u64(), rng.next_u64())
        },
        |(n, fault, rho, which, data_seed, run_seed)| {
            // Schedule purity: same seed → same drop decisions and the
            // same delay bits, whatever the query order.
            let a = FaultSchedule::new(*run_seed, *fault);
            let b = FaultSchedule::new(*run_seed, *fault);
            for w in 0..*n {
                for k in 0..100 {
                    prop_assert!(a.drops(w, k) == b.drops(w, k), "drop diverged at ({w},{k})");
                    prop_assert!(
                        a.straggler_delay(w, k).to_bits() == b.straggler_delay(w, k).to_bits(),
                        "delay diverged at ({w},{k})"
                    );
                }
            }
            let specs = [
                format!("gadmm:rho={rho}"),
                format!("qgadmm:rho={rho},bits=8"),
                format!("cgadmm:rho={rho},tau=1,mu=0.93"),
                format!("cqgadmm:rho={rho},bits=8,tau=1,mu=0.93"),
                format!("dgadmm:rho={rho},tau=15,mode=free"),
                format!("ggadmm:rho={rho},graph=complete"),
            ];
            let spec = AlgoSpec::parse(&specs[*which]).unwrap().with_fault(*fault);
            let ds = synthetic::linreg(20 * n, 6, &mut Pcg64::seeded(*data_seed));
            let p = Problem::from_dataset(&ds, *n);
            let opts = RunOptions::with_target(1e-3, 1_500);
            let costs = UnitCosts;
            let first = run(&mut *spec.build(&p, *run_seed), &p, &costs, &opts);
            let replay = run(&mut *spec.build(&p, *run_seed), &p, &costs, &opts);
            prop_assert!(
                first.same_path(&replay),
                "{spec} (fault={fault}) did not replay bit-identically"
            );
            let wide = run(
                &mut *spec.with_threads(2).build(&p, *run_seed),
                &p,
                &costs,
                &opts,
            );
            prop_assert!(
                first.same_path(&wide),
                "{spec} (fault={fault}) diverged between serial and threads=2"
            );
            Ok(())
        },
    );
}

#[test]
fn prop_fault_rate_zero_degenerates_to_unfaulted_engine() {
    // Mirror of the τ=0 censoring pin: installing the fault layer at drop
    // rate 0 must be a pure pass-through — the wrapped engine takes the
    // plain `gadmm:` / `ggadmm:` spec's exact path (Trace::same_path). At
    // the spec level rate 0 is the identity: the suffix is omitted, so the
    // faulted and plain specs are literally equal.
    check(
        "fault-rate0-degeneracy",
        1717,
        8,
        |rng| {
            let n = 2 * rng.range(2, 5);
            let rho = 1.0 + rng.range(0, 5) as f64;
            (n, rho, rng.next_u64(), rng.next_u64())
        },
        |(n, rho, data_seed, run_seed)| {
            let gadmm_spec = AlgoSpec::parse(&format!("gadmm:rho={rho}")).unwrap();
            prop_assert!(
                gadmm_spec.with_fault(0.0) == gadmm_spec,
                "fault=0 must be the spec identity"
            );
            prop_assert!(
                AlgoSpec::parse(&format!("gadmm:rho={rho},fault=0")).unwrap().spec_string()
                    == gadmm_spec.spec_string(),
                "fault=0 must be omitted from the canonical spec string"
            );
            let ds = synthetic::linreg(20 * n, 5, &mut Pcg64::seeded(*data_seed));
            let p = Problem::from_dataset(&ds, *n);
            let opts = RunOptions::with_target(1e-4, 2_000);
            let costs = UnitCosts;
            let schedule = FaultSchedule::new(*run_seed, 0.0);

            let plain_g = run(&mut *gadmm_spec.build(&p, *run_seed), &p, &costs, &opts);
            let mut faulted = Gadmm::new(&p, *rho);
            faulted.install_faults(&schedule);
            let faulted_g = run(&mut faulted, &p, &costs, &opts);
            prop_assert!(
                faulted_g.same_path(&plain_g),
                "rate-0 faulted GADMM diverged from the plain gadmm: spec"
            );

            let ggadmm_spec =
                AlgoSpec::parse(&format!("ggadmm:rho={rho},graph=complete")).unwrap();
            let plain_gg = run(&mut *ggadmm_spec.build(&p, *run_seed), &p, &costs, &opts);
            let mut faulted = Ggadmm::new(&p, *rho, GraphKind::Complete, *run_seed);
            faulted.install_faults(&schedule);
            let faulted_gg = run(&mut faulted, &p, &costs, &opts);
            prop_assert!(
                faulted_gg.same_path(&plain_gg),
                "rate-0 faulted GGADMM diverged from the plain ggadmm: spec"
            );
            Ok(())
        },
    );
}

#[test]
fn prop_dropped_slots_charge_exactly_zero_bits() {
    // Meter closed form under faults: a crash window of known size gives a
    // deterministic drop count, and every dropped slot must contribute 0
    // bits, 0 unit TC, and one censored tick — so after k iterations of
    // dense GADMM, bits = (k·N − dropped)·64·d exactly.
    check(
        "fault-zero-bit-drops",
        1818,
        20,
        |rng| {
            let n = 2 * rng.range(2, 5);
            let d = rng.range(3, 8);
            let w = rng.range(0, n);
            let crash_at = rng.range(0, 5);
            let rejoin_at = crash_at + rng.range(1, 8);
            let iters = rejoin_at + rng.range(0, 10);
            (n, d, w, crash_at, rejoin_at, iters, rng.next_u64())
        },
        |(n, d, w, crash_at, rejoin_at, iters, seed)| {
            let ds = synthetic::linreg(20 * n, *d, &mut Pcg64::seeded(*seed));
            let p = Problem::from_dataset(&ds, *n);
            let mut g = Gadmm::new(&p, 2.0);
            g.install_faults(&FaultSchedule::new(*seed, 0.0).with_crash(*w, *crash_at, *rejoin_at));
            let costs = UnitCosts;
            let mut meter = Meter::new(&costs);
            for k in 0..*iters {
                g.step(k, &mut meter);
            }
            let dropped = rejoin_at.min(iters) - crash_at.min(iters);
            let transmitted = iters * n - dropped;
            let want_bits = transmitted as f64 * 64.0 * *d as f64;
            prop_assert!(
                meter.bits == want_bits,
                "bits {} ≠ (k·N − dropped)·64·d = {want_bits}",
                meter.bits
            );
            prop_assert!(
                meter.tc_unit == transmitted as f64,
                "tc_unit {} ≠ {transmitted}",
                meter.tc_unit
            );
            prop_assert!(
                meter.censored == dropped,
                "censored {} ≠ dropped {dropped}",
                meter.censored
            );
            Ok(())
        },
    );
}

#[test]
fn prop_objective_error_never_negative_and_f_star_optimal() {
    check(
        "f-star-is-minimum",
        707,
        20,
        |rng| {
            let n = 2 * rng.range(1, 4);
            let is_logreg = rng.coin(0.5);
            let ds = if is_logreg {
                synthetic::logreg(30 * n, 5, rng)
            } else {
                synthetic::linreg(30 * n, 5, rng)
            };
            let probe = rng.normal_vec(5);
            (ds, n, probe)
        },
        |(ds, n, probe)| {
            let p = Problem::from_dataset(ds, *n);
            let at_probe = p.objective(probe);
            prop_assert!(
                at_probe >= p.f_star - 1e-9 * (1.0 + p.f_star.abs()),
                "objective at probe {at_probe} below F* {}",
                p.f_star
            );
            Ok(())
        },
    );
}

#[test]
fn prop_rgg_two_coloring_is_valid_bipartition() {
    // Whatever the placement and radius — dense, sparse, or fully
    // disconnected before stitching — the random-geometric generator must
    // deliver a valid connected bipartite graph over all N workers.
    check(
        "rgg-bipartition",
        811,
        60,
        |rng| {
            let n = rng.range(2, 33);
            let placement = Placement::random(n, 10.0, rng);
            let radius = rng.uniform(0.3, 12.0);
            (placement, radius)
        },
        |(placement, radius)| {
            let g = BipartiteGraph::random_geometric(placement, *radius)
                .map_err(|e| format!("generator failed: {e}"))?;
            prop_assert!(g.len() == placement.len(), "worker count mismatch");
            prop_assert!(
                g.heads().len() + g.tails().len() == g.len(),
                "bipartition does not cover all workers"
            );
            // Re-validating through the constructor re-checks every
            // invariant: head↔tail-only edges, no duplicates, coverage,
            // degree ≥ 1, connectivity.
            let rebuilt = BipartiteGraph::new(
                g.heads().to_vec(),
                g.tails().to_vec(),
                g.edges().to_vec(),
            );
            prop_assert!(rebuilt.is_ok(), "invalid graph: {:?}", rebuilt.err());
            Ok(())
        },
    );
}

#[test]
fn prop_ggadmm_on_chain_graph_is_trace_identical_to_gadmm() {
    // The chain-degeneracy contract of the graph generalization, on
    // *randomized* chain orders and problems: GGADMM on `from_chain(c)`
    // must take GADMM-on-`c`'s exact path (bitwise measurements, identical
    // convergence point). Engine names differ by design and are normalized
    // before the comparison.
    check(
        "ggadmm-chain-degeneracy",
        823,
        10,
        |rng| {
            let n = 2 * rng.range(2, 6);
            let data_seed = rng.next_u64();
            // Random chain: a random permutation of the physical workers.
            let order = rng.sample_indices(n, n);
            let rho = rng.uniform(1.0, 6.0);
            (n, data_seed, order, rho)
        },
        |(n, data_seed, order, rho)| {
            let ds = synthetic::linreg(20 * n, 6, &mut Pcg64::seeded(*data_seed));
            let p = Problem::from_dataset(&ds, *n);
            let chain = Chain { order: order.clone() };
            prop_assert!(chain.is_valid_permutation(), "generator produced a bad chain");
            let opts = RunOptions::with_target(1e-6, 4_000);
            let costs = UnitCosts;
            let mut g = run(&mut Gadmm::with_chain(&p, *rho, chain.clone()), &p, &costs, &opts);
            let mut gg = run(
                &mut Ggadmm::on_graph(&p, *rho, BipartiteGraph::from_chain(&chain), "chain".into()),
                &p,
                &costs,
                &opts,
            );
            g.algorithm = "group-admm".into();
            gg.algorithm = "group-admm".into();
            prop_assert!(
                gg.same_path(&g),
                "GGADMM on the chain graph diverged from GADMM (N={n}, rho={rho})"
            );
            Ok(())
        },
    );
}

#[test]
fn prop_star_graph_meter_matches_closed_form() {
    // Per-edge metering on a star: every iteration bills exactly N
    // broadcast slots of 64·d bits over 2 rounds, and the energy is the
    // hub's worst spoke link plus every spoke's link back to the hub.
    check(
        "star-meter-closed-form",
        829,
        20,
        |rng| (rng.range(3, 13), rng.next_u64()),
        |(n, seed)| {
            let mut rng = Pcg64::seeded(*seed);
            let placement = Placement::random(*n, 10.0, &mut rng);
            let costs = EnergyCostModel::new(&placement, 0);
            let ds = synthetic::linreg(20 * n, 4, &mut rng);
            let p = Problem::from_dataset(&ds, *n);
            let mut e = Ggadmm::on_graph(
                &p,
                2.0,
                BipartiteGraph::star(*n).map_err(|e| e.to_string())?,
                "star".into(),
            );
            let mut meter = Meter::new(&costs);
            let iters = 7usize;
            for k in 0..iters {
                e.step(k, &mut meter);
            }
            prop_assert!(
                meter.tc_unit == (iters * n) as f64,
                "unit TC {} != N slots per iteration {}",
                meter.tc_unit,
                iters * n
            );
            prop_assert!(meter.rounds == 2 * iters, "rounds {} != 2k", meter.rounds);
            prop_assert!(meter.censored == 0, "dense links must never censor");
            let expect_bits = (iters * n) as f64 * 64.0 * p.dim as f64;
            prop_assert!(
                meter.bits == expect_bits,
                "bits {} != closed form {expect_bits}",
                meter.bits
            );
            use gadmm::topology::LinkCosts;
            let hub = (1..*n).map(|t| costs.link(0, t)).fold(0.0, f64::max);
            let spokes: f64 = (1..*n).map(|t| costs.link(t, 0)).sum();
            let expect_energy = iters as f64 * (hub + spokes);
            prop_assert!(
                (meter.tc_energy - expect_energy).abs() <= 1e-9 * (1.0 + expect_energy),
                "energy {} != closed form {expect_energy}",
                meter.tc_energy
            );
            Ok(())
        },
    );
}

/// Build a random MLP loss (random shape, random data) plus a probe
/// point scaled to keep the tanh units away from saturation.
fn rand_mlp(rng: &mut Pcg64) -> (MlpLoss, Vec<f64>) {
    let i_dim = rng.range(2, 6);
    let h_dim = rng.range(2, 5);
    let m = rng.range(5, 25);
    let c0: Vec<f64> = (0..h_dim).map(|_| rng.uniform(-0.8, 0.8)).collect();
    let mut x = Matrix::zeros(m, i_dim);
    for v in x.data.iter_mut() {
        *v = rng.normal();
    }
    let y: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
    let loss = MlpLoss::new(x, y, c0, 1.0 / m as f64);
    let theta: Vec<f64> = (0..loss.dim()).map(|_| 0.5 * rng.normal()).collect();
    (loss, theta)
}

#[test]
fn prop_mlp_gradient_matches_central_differences() {
    // The hand-coded backward pass against second-order central
    // differences, across random architectures, datasets, and probe
    // points — the contract every MLP prox solve leans on.
    check(
        "mlp-grad-central-fd",
        1919,
        15,
        rand_mlp,
        |(loss, theta)| {
            let g = loss.grad(theta);
            let eps = 1e-6;
            let mut tp = theta.clone();
            let mut tm = theta.clone();
            for j in 0..loss.dim() {
                tp[j] = theta[j] + eps;
                tm[j] = theta[j] - eps;
                let fd = (loss.value(&tp) - loss.value(&tm)) / (2.0 * eps);
                prop_assert!(
                    (g[j] - fd).abs() <= 1e-6 * (1.0 + fd.abs()),
                    "coordinate {j}: analytic {} vs central difference {fd}",
                    g[j]
                );
                tp[j] = theta[j];
                tm[j] = theta[j];
            }
            Ok(())
        },
    );
}

#[test]
fn prop_mlp_prox_is_stationary_and_into_is_bitwise() {
    // The GD prox solver must land on a first-order stationary point of
    // φ(θ) = f(θ) + ⟨q,θ⟩ + (c/2)‖θ‖², and the allocation-free in-place
    // path must take the allocating path's exact arithmetic route.
    check(
        "mlp-prox-stationary",
        2020,
        10,
        |rng| {
            let (loss, warm) = rand_mlp(rng);
            let q: Vec<f64> = (0..loss.dim()).map(|_| 0.1 * rng.normal()).collect();
            let c = rng.uniform(0.5, 4.0);
            (loss, warm, q, c)
        },
        |(loss, warm, q, c)| {
            let theta = loss.prox_argmin(q, *c, warm);
            let r = prox_residual(loss, &theta, q, *c);
            prop_assert!(r < 1e-6, "prox residual {r} at c={c}");
            let mut out = vec![f64::NAN; loss.dim()];
            loss.prox_argmin_into(q, *c, warm, &mut out);
            prop_assert!(
                theta.iter().zip(&out).all(|(a, b)| a.to_bits() == b.to_bits()),
                "prox_argmin_into diverged from prox_argmin"
            );
            Ok(())
        },
    );
}

/// Random layer plan: 1–4 blocks of 1–4 coordinates, periods in [1, max].
fn rand_layer_plan(rng: &mut Pcg64, max_period: usize) -> (Vec<usize>, Vec<usize>) {
    let blocks = rng.range(1, 5);
    let lens: Vec<usize> = (0..blocks).map(|_| rng.range(1, 5)).collect();
    let periods: Vec<usize> = (0..blocks).map(|_| rng.range(1, max_period + 1)).collect();
    (lens, periods)
}

#[test]
fn prop_lfgadmm_dense_bits_closed_form() {
    // The layer meter's headline closed form: after K iterations of dense
    // L-FGADMM, bits = Σ_ℓ ⌈K/p_ℓ⌉·N·64·len_ℓ (layer ℓ is due whenever
    // k ≡ 0 mod p_ℓ, so it travels ⌈K/p_ℓ⌉ times from each worker), a
    // slot with no due layer is a censored tick, and every other slot
    // bills exactly one unit transmission.
    check(
        "lfgadmm-dense-bits",
        2121,
        15,
        |rng| {
            let n = 2 * rng.range(2, 5);
            let (lens, periods) = rand_layer_plan(rng, 3);
            let d: usize = lens.iter().sum();
            (synthetic::linreg(20 * n, d, rng), n, lens, periods, rng.range(1, 15))
        },
        |(ds, n, lens, periods, iters)| {
            let p = Problem::from_dataset(ds, *n);
            let mut e = Lfgadmm::new(&p, 2.0, BlockLayout::new(lens.clone()), periods.clone());
            let costs = UnitCosts;
            let mut meter = Meter::new(&costs);
            for k in 0..*iters {
                e.step(k, &mut meter);
            }
            let want_bits: f64 = lens
                .iter()
                .zip(periods)
                .map(|(&l, &pr)| iters.div_ceil(pr) as f64 * *n as f64 * FP64_BITS * l as f64)
                .sum();
            prop_assert!(
                meter.bits == want_bits,
                "bits {} ≠ Σ ⌈K/p⌉·N·64·len = {want_bits} (lens {lens:?}, periods {periods:?})",
                meter.bits
            );
            let busy = (0..*iters).filter(|k| periods.iter().any(|p| k % p == 0)).count();
            prop_assert!(
                meter.tc_unit == (busy * n) as f64,
                "tc_unit {} ≠ busy·N = {}",
                meter.tc_unit,
                busy * n
            );
            prop_assert!(
                meter.censored == (iters - busy) * n,
                "censored {} ≠ (K − busy)·N = {}",
                meter.censored,
                (iters - busy) * n
            );
            Ok(())
        },
    );
}

#[test]
fn prop_lfgadmm_quantized_layer_bits_closed_form() {
    // Quantized layer chunks bill exactly len·b + 64 range-overhead bits
    // per transmitted layer: bits = Σ_ℓ ⌈K/p_ℓ⌉·N·(len_ℓ·b + 64).
    check(
        "lfgadmm-quant-bits",
        2222,
        12,
        |rng| {
            let n = 2 * rng.range(2, 4);
            let (lens, periods) = rand_layer_plan(rng, 2);
            let d: usize = lens.iter().sum();
            let bits = rng.range(2, 11) as u32;
            (
                synthetic::linreg(20 * n, d, rng),
                n,
                lens,
                periods,
                bits,
                rng.next_u64(),
                rng.range(1, 11),
            )
        },
        |(ds, n, lens, periods, bits, seed, iters)| {
            let p = Problem::from_dataset(ds, *n);
            let layout = BlockLayout::new(lens.clone());
            let links = layer_quant_links(&layout, periods, *n, *bits, *seed);
            let mut core =
                GroupAdmmCore::new(&p, 2.0, gadmm::topology::chain::Chain::sequential(*n), links);
            let costs = UnitCosts;
            let mut meter = Meter::new(&costs);
            for k in 0..*iters {
                core.step(k, &mut meter);
            }
            let want_bits: f64 = lens
                .iter()
                .zip(periods)
                .map(|(&l, &pr)| {
                    iters.div_ceil(pr) as f64
                        * *n as f64
                        * (l as f64 * *bits as f64 + RANGE_OVERHEAD_BITS)
                })
                .sum();
            prop_assert!(
                meter.bits == want_bits,
                "bits {} ≠ Σ ⌈K/p⌉·N·(len·b + 64) = {want_bits} (b={bits})",
                meter.bits
            );
            Ok(())
        },
    );
}

#[test]
fn prop_layered_censored_twin_and_decoder_consistency() {
    // The censored layered link: the allocation-free transmit_into must be
    // bitwise the allocating transmit (message, payload bits, sender
    // view), a layered payload must bill exactly the sum of its chunks,
    // and a receiver replaying the stream through a Decoder must track the
    // sender's assembled public view — censored-due layers simply absent.
    check(
        "layer-censored-twin",
        2323,
        25,
        |rng| {
            let (lens, periods) = rand_layer_plan(rng, 3);
            let d: usize = lens.iter().sum();
            let tau = rng.uniform(0.0, 2.0);
            let mu = rng.uniform(0.5, 0.99);
            let stream: Vec<Vec<f64>> = (0..12).map(|_| rng.normal_vec(d)).collect();
            (lens, periods, tau, mu, stream)
        },
        |(lens, periods, tau, mu, stream)| {
            let layout = BlockLayout::new(lens.clone());
            let mut a = layer_censored_dense_links(&layout, periods, 1, *tau, *mu)
                .pop()
                .unwrap();
            let mut b = layer_censored_dense_links(&layout, periods, 1, *tau, *mu)
                .pop()
                .unwrap();
            let mut buf = MsgBuf::new(0);
            let mut dec = Decoder::new(layout.dim());
            for (k, model) in stream.iter().enumerate() {
                let msg = a.transmit(k, model);
                b.transmit_into(k, model, &mut buf);
                prop_assert!(buf.to_msg() == msg, "k={k}: in-place message diverged");
                prop_assert!(
                    buf.payload_bits() == msg.payload_bits(),
                    "k={k}: in-place payload bits diverged"
                );
                if let Msg::Layers(chunks) = &msg {
                    let per_chunk: f64 = chunks.iter().map(|c| c.msg.payload_bits()).sum();
                    prop_assert!(
                        msg.payload_bits() == per_chunk,
                        "k={k}: layered payload is not the sum of its chunks"
                    );
                }
                dec.apply(&msg);
                prop_assert!(
                    dec.view() == a.public_view(),
                    "k={k}: receiver view diverged from the sender's"
                );
                prop_assert!(
                    a.public_view() == b.public_view(),
                    "k={k}: twin sender views diverged"
                );
            }
            Ok(())
        },
    );
}

#[test]
fn prop_lfgadmm_faulted_bits_closed_form() {
    // Faults compose with the layer schedule: replaying the pure
    // FaultSchedule gives the exact expected meter — a dropped slot is a
    // censored tick whatever was due, every surviving slot bills its due
    // layers' dense bits, and an empty schedule slot censors too.
    check(
        "lfgadmm-fault-bits",
        2424,
        12,
        |rng| {
            let n = 2 * rng.range(2, 5);
            let (lens, periods) = rand_layer_plan(rng, 3);
            let d: usize = lens.iter().sum();
            let fault = rng.uniform(0.05, 0.35);
            (
                synthetic::linreg(20 * n, d, rng),
                n,
                lens,
                periods,
                fault,
                rng.next_u64(),
                rng.range(1, 15),
            )
        },
        |(ds, n, lens, periods, fault, seed, iters)| {
            let p = Problem::from_dataset(ds, *n);
            let mut e = Lfgadmm::new(&p, 2.0, BlockLayout::new(lens.clone()), periods.clone());
            let schedule = FaultSchedule::new(*seed, *fault);
            e.install_faults(&schedule);
            let costs = UnitCosts;
            let mut meter = Meter::new(&costs);
            for k in 0..*iters {
                e.step(k, &mut meter);
            }
            let (mut want_bits, mut want_tx, mut want_cens) = (0.0f64, 0usize, 0usize);
            for k in 0..*iters {
                let slot_bits: f64 = lens
                    .iter()
                    .zip(periods)
                    .filter(|(_, &pr)| k % pr == 0)
                    .map(|(&l, _)| FP64_BITS * l as f64)
                    .sum();
                for w in 0..*n {
                    if schedule.drops(w, k) || slot_bits == 0.0 {
                        want_cens += 1;
                    } else {
                        want_bits += slot_bits;
                        want_tx += 1;
                    }
                }
            }
            prop_assert!(
                meter.bits == want_bits,
                "bits {} ≠ fault-replayed closed form {want_bits}",
                meter.bits
            );
            prop_assert!(
                meter.tc_unit == want_tx as f64,
                "tc_unit {} ≠ {want_tx}",
                meter.tc_unit
            );
            prop_assert!(
                meter.censored == want_cens,
                "censored {} ≠ {want_cens}",
                meter.censored
            );
            Ok(())
        },
    );
}

#[test]
fn prop_file_backed_source_is_bitwise_the_in_memory_oracle() {
    // ADR-010: spilling a dataset through the binary file format changes
    // where the bytes live, not one bit of them. Rows survive the round
    // trip bitwise at every (write-chunk, read-chunk) combination, and
    // the two-pass streaming Standardizer fit on either source reproduces
    // Dataset::standardize exactly.
    let bitwise = |a: &[f64], b: &[f64]| {
        a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
    };
    check(
        "file-backed-bitwise",
        2525,
        20,
        |rng| {
            let m = rng.range(20, 120);
            let d = rng.range(2, 8);
            let ds = if rng.range(0, 2) == 0 {
                synthetic::linreg(m, d, rng)
            } else {
                synthetic::logreg(m, d, rng)
            };
            (
                ds,
                rng.range(1, 40),     // write-side chunk rows
                rng.range(1, 40),     // read-side chunk rows
                rng.range(0, 2) == 1, // has_bias
                rng.next_u64(),       // unique temp-file tag
            )
        },
        |(ds, wchunk, rchunk, has_bias, tag)| {
            let mem = InMemorySource::new(ds.clone());
            let path = std::env::temp_dir()
                .join(format!("gadmm-prop-fb-{}-{tag:x}.bin", std::process::id()));
            let fb = FileBackedSource::create(&path, &mem, *wchunk).unwrap();
            prop_assert!(
                fb.num_samples() == ds.num_samples() && fb.dim() == ds.dim(),
                "file header lost the dataset shape"
            );
            let back = materialize(&fb, *rchunk).unwrap();
            prop_assert!(
                bitwise(&back.features.data, &ds.features.data),
                "features diverged across the spill"
            );
            prop_assert!(bitwise(&back.targets, &ds.targets), "targets diverged");
            let st_fb = Standardizer::fit(&fb, *has_bias, *rchunk).unwrap();
            let st_mem = Standardizer::fit(&mem, *has_bias, *wchunk).unwrap();
            prop_assert!(
                bitwise(&st_fb.mean, &st_mem.mean) && bitwise(&st_fb.std, &st_mem.std),
                "standardizer fit depends on the source medium"
            );
            let mut want = ds.clone();
            want.standardize(*has_bias);
            let mut got = ds.clone();
            let d = got.features.cols;
            for i in 0..got.features.rows {
                st_fb.apply_row(&mut got.features.data[i * d..(i + 1) * d]);
            }
            prop_assert!(
                bitwise(&got.features.data, &want.features.data),
                "streamed standardize ≠ Dataset::standardize (bias={has_bias})"
            );
            std::fs::remove_file(&path).ok();
            Ok(())
        },
    );
}

#[test]
fn prop_sgadmm_full_batch_degenerates_to_gadmm() {
    // batch ≥ m_s makes every minibatch the whole shard; the stochastic
    // prox delegates verbatim to the exact solve, so the engine *is*
    // plain GADMM — same deterministic path, whatever epochs/seed say
    // (mirroring the τ=0 censor and rate-0 fault degeneracy pins).
    check(
        "sgadmm-degenerate",
        2626,
        10,
        |rng| {
            let n = 2 * rng.range(2, 4);
            let m = n * rng.range(8, 25);
            let d = rng.range(3, 7);
            let ds = if rng.range(0, 2) == 0 {
                synthetic::linreg(m, d, rng)
            } else {
                synthetic::logreg(m, d, rng)
            };
            (ds, n, rng.uniform(0.5, 6.0), rng.uniform(0.1, 3.0), rng.next_u64())
        },
        |(ds, n, rho, epochs, seed)| {
            let p = Problem::from_dataset(ds, *n);
            let opts = RunOptions::with_target(1e-4, 120);
            let costs = UnitCosts;
            let mut tg = run(&mut Gadmm::new(&p, *rho), &p, &costs, &opts);
            let mut s = Sgadmm::new(&p, *rho, ds.num_samples(), *epochs, *seed).unwrap();
            let mut ts = run(&mut s, &p, &costs, &opts);
            // The engines label themselves differently; the claim is about
            // the path, so pin a shared label before comparing.
            tg.algorithm = "degeneracy-pin".into();
            ts.algorithm = "degeneracy-pin".into();
            prop_assert!(
                tg.same_path(&ts),
                "batch ≥ m_s must reproduce plain GADMM bit for bit \
                 (n={n}, rho={rho}, epochs={epochs})"
            );
            Ok(())
        },
    );
}

#[test]
fn prop_from_source_problems_drive_identical_trajectories() {
    // A Problem built out-of-core (per-row-seeded stream → binary spill →
    // chunked shard assembly) must be indistinguishable *to every engine*
    // from the same data materialized and built in memory — including
    // S-GADMM, whose seeded minibatch draws index into the shards the two
    // builds assembled through different code paths.
    check(
        "from-source-trajectories",
        2727,
        8,
        |rng| {
            let n = 2 * rng.range(2, 4);
            let m = n * rng.range(6, 16) + rng.range(0, n); // often uneven
            let d = rng.range(3, 7);
            let task = if rng.range(0, 2) == 0 {
                Task::LinearRegression
            } else {
                Task::LogisticRegression
            };
            (task, m, d, n, rng.uniform(1.0, 50.0), rng.range(1, 30), rng.next_u64())
        },
        |(task, m, d, n, kappa, chunk, seed)| {
            let stream = SyntheticStream::new(*task, *m, *d, *kappa, *seed);
            let path = std::env::temp_dir()
                .join(format!("gadmm-prop-src-{}-{seed:x}.bin", std::process::id()));
            let fb = FileBackedSource::create(&path, &stream, *chunk).unwrap();
            let p_file = Problem::from_source(&fb, *n, *chunk).unwrap();
            let ds = materialize(&fb, *chunk).unwrap();
            let p_mem = Problem::from_dataset(&ds, *n);
            std::fs::remove_file(&path).ok();
            let opts = RunOptions::with_target(1e-3, 60);
            let costs = UnitCosts;
            let tg_f = run(&mut Gadmm::new(&p_file, 3.0), &p_file, &costs, &opts);
            let tg_m = run(&mut Gadmm::new(&p_mem, 3.0), &p_mem, &costs, &opts);
            prop_assert!(tg_f.same_path(&tg_m), "GADMM saw different problems");
            let mut sf = Sgadmm::new(&p_file, 3.0, 4, 1.0, *seed).unwrap();
            let mut sm = Sgadmm::new(&p_mem, 3.0, 4, 1.0, *seed).unwrap();
            let ts_f = run(&mut sf, &p_file, &costs, &opts);
            let ts_m = run(&mut sm, &p_mem, &costs, &opts);
            prop_assert!(ts_f.same_path(&ts_m), "S-GADMM saw different problems");
            Ok(())
        },
    );
}
