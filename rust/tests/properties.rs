//! Property tests (randomized, via util::prop) for the paper's invariants:
//! chain validity, Lyapunov monotonicity (Theorem 2), tail dual
//! feasibility (eq. 20), primal-residual decay, and TC accounting.

use gadmm::comm::Meter;
use gadmm::data::synthetic;
use gadmm::linalg::vector as vec_ops;
use gadmm::model::Problem;
use gadmm::optim::{solver, Engine, Gadmm};
use gadmm::prop_assert;
use gadmm::topology::chain::{self, Chain};
use gadmm::topology::{EnergyCostModel, Placement, UnitCosts};
use gadmm::util::prop::check;
use gadmm::util::rng::Pcg64;

/// Random even worker count in [4, 20].
fn rand_even_n(rng: &mut Pcg64) -> usize {
    2 * rng.range(2, 11)
}

#[test]
fn prop_appendix_d_chain_is_valid_alternating_hamiltonian() {
    check(
        "appendix-d-chain",
        101,
        60,
        |rng| {
            let n = rand_even_n(rng);
            let placement = Placement::random(n, 10.0, rng);
            let costs = EnergyCostModel::new(&placement, placement.central_worker());
            let heads = chain::draw_heads(n, rng);
            (n, heads.clone(), chain::greedy_chain(n, &heads, &costs))
        },
        |(n, heads, c)| {
            prop_assert!(c.is_valid_permutation(), "not a permutation: {c:?}");
            prop_assert!(c.order[0] == 0, "first position must be worker 0");
            prop_assert!(c.order[*n - 1] == n - 1, "last position must be worker N-1");
            for (p, w) in c.order.iter().enumerate() {
                let is_head = heads.contains(w);
                prop_assert!(
                    is_head == Chain::is_head_position(p),
                    "worker {w} at position {p} violates head/tail alternation"
                );
            }
            Ok(())
        },
    );
}

#[test]
fn prop_gadmm_lyapunov_monotone_nonincreasing() {
    // Theorem 2: V_k (eq. 32) decreases monotonically. Uses the exact λ*
    // from the dual-feasibility telescede at θ* (optim::solver).
    check(
        "lyapunov-monotone",
        202,
        12,
        |rng| {
            let n = 2 * rng.range(2, 5);
            let m = 40 * n;
            let ds = synthetic::linreg(m, 6, rng);
            let rho = rng.uniform(0.5, 6.0);
            (ds, n, rho)
        },
        |(ds, n, rho)| {
            let p = Problem::from_dataset(ds, *n);
            let mut g = Gadmm::new(&p, *rho);
            let order: Vec<usize> = (0..*n).collect();
            let lambda_star = solver::optimal_duals(&p.losses, &order, &p.theta_star);
            let costs = UnitCosts;
            let mut meter = Meter::new(&costs);
            let mut v_prev = g.lyapunov(&p.theta_star, &lambda_star);
            for k in 0..60 {
                g.step(k, &mut meter);
                let v = g.lyapunov(&p.theta_star, &lambda_star);
                prop_assert!(
                    v <= v_prev * (1.0 + 1e-9),
                    "V increased at iteration {k}: {v_prev} → {v} (rho={rho})"
                );
                v_prev = v;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_tail_dual_feasibility_exact() {
    // Eq. 20: after every iteration the tail workers' dual feasibility
    // holds exactly (up to float error), on arbitrary chains.
    check(
        "tail-dual-feasibility",
        303,
        15,
        |rng| {
            let n = rand_even_n(rng);
            let ds = synthetic::linreg(30 * n, 5, rng);
            // Random valid chain with fixed ends.
            let mut middle: Vec<usize> = (1..n - 1).collect();
            rng.shuffle(&mut middle);
            let mut order = vec![0];
            order.extend(middle);
            order.push(n - 1);
            (ds, n, order, rng.uniform(0.5, 5.0))
        },
        |(ds, n, order, rho)| {
            let p = Problem::from_dataset(ds, *n);
            let mut g = Gadmm::with_chain(&p, *rho, Chain { order: order.clone() });
            let costs = UnitCosts;
            let mut meter = Meter::new(&costs);
            for k in 0..10 {
                g.step(k, &mut meter);
                let r = g.tail_dual_residual();
                prop_assert!(r < 1e-6, "tail dual residual {r} at iteration {k}");
            }
            Ok(())
        },
    );
}

#[test]
fn prop_primal_residuals_decay() {
    check(
        "primal-residual-decay",
        404,
        10,
        |rng| {
            let n = 2 * rng.range(2, 5);
            (synthetic::linreg(40 * n, 6, rng), n)
        },
        |(ds, n)| {
            let p = Problem::from_dataset(ds, *n);
            let mut g = Gadmm::new(&p, 3.0);
            let costs = UnitCosts;
            let mut meter = Meter::new(&costs);
            let early: f64 = {
                for k in 0..5 {
                    g.step(k, &mut meter);
                }
                g.primal_residuals().iter().map(|r| vec_ops::norm2(r)).sum()
            };
            for k in 5..300 {
                g.step(k, &mut meter);
            }
            let late: f64 = g.primal_residuals().iter().map(|r| vec_ops::norm2(r)).sum();
            prop_assert!(
                late < early * 0.1 || late < 1e-8,
                "primal residuals did not decay: {early} → {late}"
            );
            Ok(())
        },
    );
}

#[test]
fn prop_tc_accounting_closed_form() {
    // For GADMM under unit costs, TC after k iterations is exactly k·N and
    // rounds are exactly 2k, for any chain.
    check(
        "tc-closed-form",
        505,
        20,
        |rng| {
            let n = rand_even_n(rng);
            (synthetic::linreg(20 * n, 4, rng), n, rng.range(1, 30))
        },
        |(ds, n, iters)| {
            let p = Problem::from_dataset(ds, *n);
            let mut g = Gadmm::new(&p, 2.0);
            let costs = UnitCosts;
            let mut meter = Meter::new(&costs);
            for k in 0..*iters {
                g.step(k, &mut meter);
            }
            prop_assert!(
                meter.tc_unit == (*iters * *n) as f64,
                "TC {} ≠ k·N = {}",
                meter.tc_unit,
                iters * n
            );
            prop_assert!(meter.rounds == 2 * iters, "rounds {} ≠ 2k", meter.rounds);
            Ok(())
        },
    );
}

#[test]
fn prop_energy_tc_scales_with_area() {
    // Free-space d² law: scaling the placement area by s scales every
    // energy cost by s².
    check(
        "energy-area-scaling",
        606,
        30,
        |rng| {
            let n = rand_even_n(rng);
            let base = Placement::random(n, 10.0, rng);
            let scale = rng.uniform(2.0, 10.0);
            (base, scale)
        },
        |(base, scale)| {
            let scaled = Placement {
                side: base.side * scale,
                positions: base
                    .positions
                    .iter()
                    .map(|&(x, y)| (x * scale, y * scale))
                    .collect(),
            };
            let c1 = EnergyCostModel::new(base, 0);
            let c2 = EnergyCostModel::new(&scaled, 0);
            use gadmm::topology::LinkCosts;
            for a in 0..base.len() {
                for b in 0..base.len() {
                    if a == b || base.distance(a, b) < 1e-3 {
                        continue;
                    }
                    let ratio = c2.link(a, b) / c1.link(a, b);
                    prop_assert!(
                        (ratio - scale * scale).abs() < 1e-6 * scale * scale,
                        "link ({a},{b}) ratio {ratio} ≠ s² = {}",
                        scale * scale
                    );
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_objective_error_never_negative_and_f_star_optimal() {
    check(
        "f-star-is-minimum",
        707,
        20,
        |rng| {
            let n = 2 * rng.range(1, 4);
            let is_logreg = rng.coin(0.5);
            let ds = if is_logreg {
                synthetic::logreg(30 * n, 5, rng)
            } else {
                synthetic::linreg(30 * n, 5, rng)
            };
            let probe = rng.normal_vec(5);
            (ds, n, probe)
        },
        |(ds, n, probe)| {
            let p = Problem::from_dataset(ds, *n);
            let at_probe = p.objective(probe);
            prop_assert!(
                at_probe >= p.f_star - 1e-9 * (1.0 + p.f_star.abs()),
                "objective at probe {at_probe} below F* {}",
                p.f_star
            );
            Ok(())
        },
    );
}
