//! Distributed-coordinator integration: thread/channel execution must be
//! exactly equivalent to the sequential engine, across chains, tasks and
//! worker counts, and must shut down cleanly.

use gadmm::coordinator::{self, QuantSpec};
use gadmm::data::synthetic;
use gadmm::linalg::vector as vec_ops;
use gadmm::model::Problem;
use gadmm::optim::{self, run, Gadmm, Qgadmm, RunOptions};
use gadmm::runtime::{LocalSolver, NativeSolver};
use gadmm::session::AlgoSpec;
use gadmm::topology::chain::Chain;
use gadmm::topology::UnitCosts;
use gadmm::util::rng::Pcg64;

fn native_solvers(p: &Problem) -> Vec<Box<dyn LocalSolver + Send + '_>> {
    (0..p.num_workers())
        .map(|w| Box::new(NativeSolver::new(&*p.losses[w])) as Box<dyn LocalSolver + Send + '_>)
        .collect()
}

#[test]
fn equivalence_across_worker_counts() {
    for n in [2usize, 4, 8, 12] {
        let ds = synthetic::linreg(24 * n, 6, &mut Pcg64::seeded(n as u64));
        let p = Problem::from_dataset(&ds, n);
        let opts = RunOptions::with_target(1e-5, 5_000);
        let costs = UnitCosts;
        let dist = coordinator::train(&p, native_solvers(&p), 2.0, Chain::sequential(n), &costs, &opts);
        let mut seq = Gadmm::new(&p, 2.0);
        let seq_trace = run(&mut seq, &p, &costs, &opts);
        assert_eq!(
            dist.trace.iters_to_target(),
            seq_trace.iters_to_target(),
            "N={n}"
        );
        for (a, b) in dist.thetas.iter().zip(seq.thetas()) {
            assert!(vec_ops::dist2(a, b) < 1e-9, "N={n} model divergence");
        }
    }
}

#[test]
fn equivalence_on_permuted_chain_logreg() {
    let ds = synthetic::logreg(160, 6, &mut Pcg64::seeded(5));
    let p = Problem::from_dataset(&ds, 8);
    let chain = Chain {
        order: vec![0, 5, 2, 6, 4, 1, 3, 7],
    };
    let opts = RunOptions::with_target(1e-4, 4_000);
    let costs = UnitCosts;
    let dist = coordinator::train(&p, native_solvers(&p), 0.3, chain.clone(), &costs, &opts);
    let mut seq = Gadmm::with_chain(&p, 0.3, chain);
    let seq_trace = run(&mut seq, &p, &costs, &opts);
    assert_eq!(dist.trace.iters_to_target(), seq_trace.iters_to_target());
    // Traces agree record by record.
    for (a, b) in dist.trace.records.iter().zip(&seq_trace.records) {
        assert!((a.obj_err - b.obj_err).abs() <= 1e-9 * (1.0 + b.obj_err));
        assert_eq!(a.acv, b.acv);
    }
}

#[test]
fn quantized_distributed_matches_sequential_qgadmm() {
    // The distributed Q-GADMM path (per-worker quantizers on the wire,
    // mirrored duals over decoded public models) must be *bit-identical*
    // to the sequential engine: same per-worker rounding seeds, same f64
    // arithmetic, same trace.
    let ds = synthetic::linreg(120, 6, &mut Pcg64::seeded(9));
    let p = Problem::from_dataset(&ds, 6);
    let opts = RunOptions::with_target(1e-5, 4_000);
    let costs = UnitCosts;
    let quant = QuantSpec { bits: 8, seed: 17 };

    let dist = coordinator::train_with(
        &p,
        native_solvers(&p),
        3.0,
        Chain::sequential(6),
        &costs,
        &opts,
        Some(quant),
    );
    let mut seq = Qgadmm::new(&p, 3.0, quant.bits, quant.seed);
    let seq_trace = run(&mut seq, &p, &costs, &opts);

    assert_eq!(
        dist.trace.iters_to_target(),
        seq_trace.iters_to_target(),
        "distributed and sequential Q-GADMM must converge identically"
    );
    for (a, b) in dist.trace.records.iter().zip(&seq_trace.records) {
        // The leader sums worker loss reports in arrival order, so the
        // monitoring objective may differ by float-summation noise; the
        // models and the accounting must agree exactly.
        assert!(
            (a.obj_err - b.obj_err).abs() <= 1e-9 * (1.0 + b.obj_err),
            "iter {}: {} vs {}",
            a.iter,
            a.obj_err,
            b.obj_err
        );
        assert_eq!(a.tc_unit, b.tc_unit);
        assert_eq!(a.bits, b.bits, "iter {}: bit accounting mismatch", a.iter);
    }
    for (a, b) in dist.thetas.iter().zip(seq.thetas()) {
        assert_eq!(a, b, "final model mismatch");
    }
}

#[test]
fn quantized_distributed_on_permuted_chain_converges() {
    let ds = synthetic::linreg(80, 5, &mut Pcg64::seeded(10));
    let p = Problem::from_dataset(&ds, 6);
    let chain = Chain {
        order: vec![0, 3, 2, 4, 1, 5],
    };
    let opts = RunOptions::with_target(1e-4, 6_000);
    let costs = UnitCosts;
    let dist = coordinator::train_with(
        &p,
        native_solvers(&p),
        2.0,
        chain.clone(),
        &costs,
        &opts,
        Some(QuantSpec { bits: 6, seed: 4 }),
    );
    assert!(
        dist.trace.iters_to_target().is_some(),
        "err {}",
        dist.trace.final_error()
    );
    let mut seq = Qgadmm::with_chain(&p, 2.0, 6, 4, chain);
    let seq_trace = run(&mut seq, &p, &costs, &opts);
    assert_eq!(dist.trace.iters_to_target(), seq_trace.iters_to_target());
}

/// Distributed run of a static-chain spec must be bit-identical to the
/// sequential core built from the same spec: identical slot/bit
/// accounting at every recorded iteration and bitwise-equal final models
/// (the monitoring objective alone may differ by float-summation order).
fn assert_dist_matches_seq(p: &Problem, spec: AlgoSpec, seed: u64, opts: &RunOptions) {
    let costs = UnitCosts;
    let n = p.num_workers();
    let dist = coordinator::train_spec(
        p,
        native_solvers(p),
        &spec,
        seed,
        Chain::sequential(n),
        &costs,
        opts,
    )
    .unwrap();
    let mut seq = spec.build(p, seed);
    let seq_trace = run(&mut *seq, p, &costs, opts);
    assert_eq!(
        dist.trace.iters_to_target(),
        seq_trace.iters_to_target(),
        "{spec}: convergence point differs"
    );
    assert_eq!(dist.trace.records.len(), seq_trace.records.len(), "{spec}");
    for (a, b) in dist.trace.records.iter().zip(&seq_trace.records) {
        assert!(
            (a.obj_err - b.obj_err).abs() <= 1e-9 * (1.0 + b.obj_err),
            "{spec} iter {}: {} vs {}",
            a.iter,
            a.obj_err,
            b.obj_err
        );
        assert_eq!(a.tc_unit, b.tc_unit, "{spec} iter {}: TC mismatch", a.iter);
        assert_eq!(a.bits, b.bits, "{spec} iter {}: bit accounting mismatch", a.iter);
        assert_eq!(a.acv, b.acv, "{spec} iter {}: ACV mismatch", a.iter);
    }
}

#[test]
fn censored_distributed_matches_sequential_cgadmm() {
    // Skips must happen on both paths at the same slots: the censor check
    // runs inside the same shared LinkPolicy on either side.
    let ds = synthetic::linreg(120, 8, &mut Pcg64::seeded(11));
    let p = Problem::from_dataset(&ds, 6);
    let opts = RunOptions::with_target(1e-5, 4_000);
    let spec = AlgoSpec::Cgadmm { rho: 5.0, tau: 1.0, mu: 0.93, fault: 0.0, threads: 1 };
    assert_dist_matches_seq(&p, spec, 3, &opts);
    // The run censored something (otherwise this test is vacuous): TC at
    // convergence below k·N.
    let seq = run(&mut *spec.build(&p, 3), &p, &UnitCosts, &opts);
    let k = seq.iters_to_target().expect("C-GADMM converges") as f64;
    assert!(seq.tc_to_target().unwrap() < k * 6.0, "no slot censored");
}

#[test]
fn censored_quantized_distributed_matches_sequential_cqgadmm() {
    let ds = synthetic::linreg(120, 8, &mut Pcg64::seeded(12));
    let p = Problem::from_dataset(&ds, 6);
    let opts = RunOptions::with_target(1e-5, 5_000);
    assert_dist_matches_seq(
        &p,
        AlgoSpec::Cqgadmm { rho: 5.0, bits: 8, tau: 1.0, mu: 0.93, fault: 0.0, threads: 1 },
        17,
        &opts,
    );
}

#[test]
fn all_static_chain_specs_distribute_bit_identically() {
    // The acceptance sweep: every engine the coordinator implements stays
    // bit-identical to its sequential core.
    let ds = synthetic::linreg(120, 6, &mut Pcg64::seeded(13));
    let p = Problem::from_dataset(&ds, 4);
    let opts = RunOptions::with_target(1e-4, 3_000);
    for spec in [
        AlgoSpec::Gadmm { rho: 3.0, fault: 0.0, threads: 1 },
        AlgoSpec::Qgadmm { rho: 3.0, bits: 6, fault: 0.0, threads: 1 },
        AlgoSpec::Cgadmm { rho: 3.0, tau: 0.5, mu: 0.9, fault: 0.0, threads: 1 },
        AlgoSpec::Cqgadmm { rho: 3.0, bits: 6, tau: 0.5, mu: 0.9, fault: 0.0, threads: 1 },
    ] {
        assert_dist_matches_seq(&p, spec, 9, &opts);
    }
}

#[test]
fn layer_scheduled_distributed_matches_sequential_lfgadmm() {
    // The layer schedule is k-pure and lives inside the shared LinkPolicy,
    // so a stale layer is absent from the wire message on both execution
    // paths at exactly the same rounds: the channel run must reproduce the
    // sequential L-FGADMM engine's slot, bit, and ACV accounting exactly.
    let ds = synthetic::linreg(120, 6, &mut Pcg64::seeded(13));
    let p = Problem::from_dataset(&ds, 4);
    let opts = RunOptions::with_target(1e-5, 5_000);
    let spec = AlgoSpec::parse("lfgadmm:rho=5,layers=4-2,periods=1-2").unwrap();
    assert_dist_matches_seq(&p, spec, 13, &opts);
    // Not vacuous: the period-2 tail layer really stales — bits at
    // convergence strictly below the every-round dense closed form k·N·64·d.
    let seq = run(&mut *spec.build(&p, 13), &p, &UnitCosts, &opts);
    let k = seq.iters_to_target().expect("L-FGADMM converges on the pin config") as f64;
    assert!(
        seq.bits_to_target().unwrap() < k * 4.0 * 64.0 * 6.0,
        "period-2 layer staled nothing"
    );
    // Chaos composes with the schedule on the wire as well: seeded drops
    // hit the same slots on both paths, layered payloads included.
    let faulted = AlgoSpec::parse("lfgadmm:rho=5,layers=4-2,periods=1-2,fault=0.1").unwrap();
    assert_dist_matches_seq(&p, faulted, 13, &opts);
}

#[test]
fn tau_zero_distributed_cqgadmm_equals_distributed_qgadmm() {
    // Degeneracy holds across the wire too: τ=0 censoring is Q-GADMM.
    let ds = synthetic::linreg(80, 5, &mut Pcg64::seeded(14));
    let p = Problem::from_dataset(&ds, 4);
    let opts = RunOptions::with_target(1e-5, 3_000);
    let costs = UnitCosts;
    let cq = coordinator::train_spec(
        &p,
        native_solvers(&p),
        &AlgoSpec::Cqgadmm { rho: 3.0, bits: 8, tau: 0.0, mu: 0.93, fault: 0.0, threads: 1 },
        21,
        Chain::sequential(4),
        &costs,
        &opts,
    )
    .unwrap();
    let q = coordinator::train_spec(
        &p,
        native_solvers(&p),
        &AlgoSpec::Qgadmm { rho: 3.0, bits: 8, fault: 0.0, threads: 1 },
        21,
        Chain::sequential(4),
        &costs,
        &opts,
    )
    .unwrap();
    assert_eq!(cq.trace.records.len(), q.trace.records.len());
    for (a, b) in cq.trace.records.iter().zip(&q.trace.records) {
        assert_eq!(a.bits, b.bits);
        assert_eq!(a.tc_unit, b.tc_unit);
    }
    for (a, b) in cq.thetas.iter().zip(&q.thetas) {
        assert_eq!(a, b, "τ=0 final models differ");
    }
}

#[test]
fn faulted_chain_specs_distribute_bit_identically() {
    // Chaos equivalence on a chain: a `fault=p` spec drops the same seeded
    // slots on both execution paths — in the sequential core the dropped
    // broadcast is a Msg::Skip from the installed FaultyLink, on the wire
    // it is the same Skip travelling as a receiver timeout — so the
    // distributed trace must stay bit-identical to the sequential one
    // (slot and bit accounting included) at nonzero drop rates.
    let ds = synthetic::linreg(120, 6, &mut Pcg64::seeded(19));
    let p = Problem::from_dataset(&ds, 6);
    let opts = RunOptions::with_target(1e-4, 8_000);
    for spec in [
        AlgoSpec::Gadmm { rho: 3.0, fault: 0.1, threads: 1 },
        AlgoSpec::Qgadmm { rho: 3.0, bits: 8, fault: 0.1, threads: 1 },
        AlgoSpec::Cqgadmm { rho: 3.0, bits: 8, tau: 0.5, mu: 0.93, fault: 0.05, threads: 1 },
    ] {
        assert_dist_matches_seq(&p, spec, 23, &opts);
    }
    // The pin is not vacuous: the faulted GADMM run really lost slots
    // (unit TC strictly below the k·N of a clean run).
    let spec = AlgoSpec::Gadmm { rho: 3.0, fault: 0.1, threads: 1 };
    let seq = run(&mut *spec.build(&p, 23), &p, &UnitCosts, &opts);
    let last = seq.records.last().expect("trace has records");
    assert!(
        last.tc_unit < (last.iter * 6) as f64,
        "fault=0.1 dropped nothing: tc {} at iter {}",
        last.tc_unit,
        last.iter
    );
}

#[test]
fn faulted_star_ggadmm_distributed_matches_sequential() {
    // Chaos equivalence off the chain: the graph coordinator wraps its
    // dense links in the same seed-keyed FaultSchedule the sequential
    // engine installs, so a faulted GGADMM star run matches the faulted
    // sequential engine record by record.
    use gadmm::optim::Ggadmm;
    use gadmm::topology::graph::GraphKind;
    use gadmm::topology::Placement;

    let ds = synthetic::linreg(100, 6, &mut Pcg64::seeded(5));
    let p = Problem::from_dataset(&ds, 5);
    let opts = RunOptions::with_target(1e-4, 8_000);
    let costs = UnitCosts;
    let spec = AlgoSpec::Ggadmm { rho: 3.0, graph: GraphKind::Star, fault: 0.1, threads: 1 };
    let graph = GraphKind::Star
        .build(5, &Placement::random(5, 10.0, &mut Pcg64::seeded(9)))
        .unwrap();
    let dist = coordinator::train_graph_spec(&p, native_solvers(&p), &spec, 1, graph, &costs, &opts)
        .unwrap();
    let mut seq = Ggadmm::new(&p, 3.0, GraphKind::Star, 1);
    seq.install_faults(&gadmm::comm::FaultSchedule::new(1, 0.1));
    let seq_trace = run(&mut seq, &p, &costs, &opts);
    assert_eq!(dist.trace.iters_to_target(), seq_trace.iters_to_target());
    assert_eq!(dist.trace.records.len(), seq_trace.records.len());
    for (a, b) in dist.trace.records.iter().zip(&seq_trace.records) {
        assert!(
            (a.obj_err - b.obj_err).abs() <= 1e-9 * (1.0 + b.obj_err),
            "iter {}: {} vs {}",
            a.iter,
            a.obj_err,
            b.obj_err
        );
        assert_eq!(a.tc_unit, b.tc_unit, "iter {}: TC mismatch", a.iter);
        assert_eq!(a.bits, b.bits, "iter {}: bit accounting mismatch", a.iter);
    }
    for (a, b) in dist.thetas.iter().zip(seq.thetas()) {
        assert!(vec_ops::dist2(a, b) < 1e-9, "final model mismatch");
    }
    assert!(
        dist.trace.algorithm.contains("fault=0.1"),
        "the distributed name must surface the drop rate: {}",
        dist.trace.algorithm
    );
}

#[test]
fn dgadmm_spec_still_rejected_by_coordinator() {
    let ds = synthetic::linreg(80, 5, &mut Pcg64::seeded(15));
    let p = Problem::from_dataset(&ds, 4);
    let opts = RunOptions::with_target(1e-4, 100);
    let err = coordinator::train_spec(
        &p,
        native_solvers(&p),
        &AlgoSpec::Dgadmm { rho: 1.0, tau: 15, mode: optim::RechainMode::Free, fault: 0.0, threads: 1 },
        1,
        Chain::sequential(4),
        &UnitCosts,
        &opts,
    )
    .err()
    .expect("re-chaining specs must be rejected");
    assert!(err.contains("C-GADMM/CQ-GADMM"), "{err}");
}

#[test]
fn early_termination_on_cap_shuts_down_cleanly() {
    let ds = synthetic::linreg(80, 5, &mut Pcg64::seeded(6));
    let p = Problem::from_dataset(&ds, 4);
    let opts = RunOptions::with_target(0.0, 13); // will hit the cap
    let costs = UnitCosts;
    let result = coordinator::train(&p, native_solvers(&p), 2.0, Chain::sequential(4), &costs, &opts);
    assert_eq!(result.trace.records.len(), 13);
    assert!(result.trace.iters_to_target().is_none());
    // Reaching here at all proves the worker threads joined.
}

#[test]
fn repeated_runs_are_deterministic() {
    let ds = synthetic::linreg(80, 5, &mut Pcg64::seeded(7));
    let p = Problem::from_dataset(&ds, 6);
    let opts = RunOptions::with_target(1e-6, 3_000);
    let costs = UnitCosts;
    let a = coordinator::train(&p, native_solvers(&p), 3.0, Chain::sequential(6), &costs, &opts);
    let b = coordinator::train(&p, native_solvers(&p), 3.0, Chain::sequential(6), &costs, &opts);
    assert_eq!(a.trace.iters_to_target(), b.trace.iters_to_target());
    assert_eq!(a.consensus, b.consensus);
}
