//! Distributed-coordinator integration: thread/channel execution must be
//! exactly equivalent to the sequential engine, across chains, tasks and
//! worker counts, and must shut down cleanly.

use gadmm::coordinator::{self, QuantSpec};
use gadmm::data::synthetic;
use gadmm::linalg::vector as vec_ops;
use gadmm::model::Problem;
use gadmm::optim::{run, Gadmm, Qgadmm, RunOptions};
use gadmm::runtime::{LocalSolver, NativeSolver};
use gadmm::topology::chain::Chain;
use gadmm::topology::UnitCosts;
use gadmm::util::rng::Pcg64;

fn native_solvers(p: &Problem) -> Vec<Box<dyn LocalSolver + Send + '_>> {
    (0..p.num_workers())
        .map(|w| Box::new(NativeSolver::new(&*p.losses[w])) as Box<dyn LocalSolver + Send + '_>)
        .collect()
}

#[test]
fn equivalence_across_worker_counts() {
    for n in [2usize, 4, 8, 12] {
        let ds = synthetic::linreg(24 * n, 6, &mut Pcg64::seeded(n as u64));
        let p = Problem::from_dataset(&ds, n);
        let opts = RunOptions::with_target(1e-5, 5_000);
        let costs = UnitCosts;
        let dist = coordinator::train(&p, native_solvers(&p), 2.0, Chain::sequential(n), &costs, &opts);
        let mut seq = Gadmm::new(&p, 2.0);
        let seq_trace = run(&mut seq, &p, &costs, &opts);
        assert_eq!(
            dist.trace.iters_to_target(),
            seq_trace.iters_to_target(),
            "N={n}"
        );
        for (a, b) in dist.thetas.iter().zip(seq.thetas()) {
            assert!(vec_ops::dist2(a, b) < 1e-9, "N={n} model divergence");
        }
    }
}

#[test]
fn equivalence_on_permuted_chain_logreg() {
    let ds = synthetic::logreg(160, 6, &mut Pcg64::seeded(5));
    let p = Problem::from_dataset(&ds, 8);
    let chain = Chain {
        order: vec![0, 5, 2, 6, 4, 1, 3, 7],
    };
    let opts = RunOptions::with_target(1e-4, 4_000);
    let costs = UnitCosts;
    let dist = coordinator::train(&p, native_solvers(&p), 0.3, chain.clone(), &costs, &opts);
    let mut seq = Gadmm::with_chain(&p, 0.3, chain);
    let seq_trace = run(&mut seq, &p, &costs, &opts);
    assert_eq!(dist.trace.iters_to_target(), seq_trace.iters_to_target());
    // Traces agree record by record.
    for (a, b) in dist.trace.records.iter().zip(&seq_trace.records) {
        assert!((a.obj_err - b.obj_err).abs() <= 1e-9 * (1.0 + b.obj_err));
        assert_eq!(a.acv, b.acv);
    }
}

#[test]
fn quantized_distributed_matches_sequential_qgadmm() {
    // The distributed Q-GADMM path (per-worker quantizers on the wire,
    // mirrored duals over decoded public models) must be *bit-identical*
    // to the sequential engine: same per-worker rounding seeds, same f64
    // arithmetic, same trace.
    let ds = synthetic::linreg(120, 6, &mut Pcg64::seeded(9));
    let p = Problem::from_dataset(&ds, 6);
    let opts = RunOptions::with_target(1e-5, 4_000);
    let costs = UnitCosts;
    let quant = QuantSpec { bits: 8, seed: 17 };

    let dist = coordinator::train_with(
        &p,
        native_solvers(&p),
        3.0,
        Chain::sequential(6),
        &costs,
        &opts,
        Some(quant),
    );
    let mut seq = Qgadmm::new(&p, 3.0, quant.bits, quant.seed);
    let seq_trace = run(&mut seq, &p, &costs, &opts);

    assert_eq!(
        dist.trace.iters_to_target(),
        seq_trace.iters_to_target(),
        "distributed and sequential Q-GADMM must converge identically"
    );
    for (a, b) in dist.trace.records.iter().zip(&seq_trace.records) {
        // The leader sums worker loss reports in arrival order, so the
        // monitoring objective may differ by float-summation noise; the
        // models and the accounting must agree exactly.
        assert!(
            (a.obj_err - b.obj_err).abs() <= 1e-9 * (1.0 + b.obj_err),
            "iter {}: {} vs {}",
            a.iter,
            a.obj_err,
            b.obj_err
        );
        assert_eq!(a.tc_unit, b.tc_unit);
        assert_eq!(a.bits, b.bits, "iter {}: bit accounting mismatch", a.iter);
    }
    for (a, b) in dist.thetas.iter().zip(seq.thetas()) {
        assert_eq!(a, b, "final model mismatch");
    }
}

#[test]
fn quantized_distributed_on_permuted_chain_converges() {
    let ds = synthetic::linreg(80, 5, &mut Pcg64::seeded(10));
    let p = Problem::from_dataset(&ds, 6);
    let chain = Chain {
        order: vec![0, 3, 2, 4, 1, 5],
    };
    let opts = RunOptions::with_target(1e-4, 6_000);
    let costs = UnitCosts;
    let dist = coordinator::train_with(
        &p,
        native_solvers(&p),
        2.0,
        chain.clone(),
        &costs,
        &opts,
        Some(QuantSpec { bits: 6, seed: 4 }),
    );
    assert!(
        dist.trace.iters_to_target().is_some(),
        "err {}",
        dist.trace.final_error()
    );
    let mut seq = Qgadmm::with_chain(&p, 2.0, 6, 4, chain);
    let seq_trace = run(&mut seq, &p, &costs, &opts);
    assert_eq!(dist.trace.iters_to_target(), seq_trace.iters_to_target());
}

#[test]
fn early_termination_on_cap_shuts_down_cleanly() {
    let ds = synthetic::linreg(80, 5, &mut Pcg64::seeded(6));
    let p = Problem::from_dataset(&ds, 4);
    let opts = RunOptions::with_target(0.0, 13); // will hit the cap
    let costs = UnitCosts;
    let result = coordinator::train(&p, native_solvers(&p), 2.0, Chain::sequential(4), &costs, &opts);
    assert_eq!(result.trace.records.len(), 13);
    assert!(result.trace.iters_to_target().is_none());
    // Reaching here at all proves the worker threads joined.
}

#[test]
fn repeated_runs_are_deterministic() {
    let ds = synthetic::linreg(80, 5, &mut Pcg64::seeded(7));
    let p = Problem::from_dataset(&ds, 6);
    let opts = RunOptions::with_target(1e-6, 3_000);
    let costs = UnitCosts;
    let a = coordinator::train(&p, native_solvers(&p), 3.0, Chain::sequential(6), &costs, &opts);
    let b = coordinator::train(&p, native_solvers(&p), 3.0, Chain::sequential(6), &costs, &opts);
    assert_eq!(a.trace.iters_to_target(), b.trace.iters_to_target());
    assert_eq!(a.consensus, b.consensus);
}
