//! Execution-backend equivalence pins: the intra-group `Exec` pool
//! (`threads=K`) must be *bit-identical* to serial execution for every
//! group engine, on every topology, at every width.
//!
//! This is the contract that makes `threads=K` a pure wall-clock knob
//! (docs/adr/005-exec-backend.md): each phase task writes only its own
//! worker/dual slots, so parallel scheduling cannot change the arithmetic.
//! The pins run the paper's linreg and logreg configurations through all
//! six core-backed engines (GADMM / Q-GADMM / C-GADMM / CQ-GADMM /
//! D-GADMM / GGADMM) and compare whole traces with `Trace::same_path`
//! (bitwise measurements, wall-clock excluded).

use gadmm::data::synthetic;
use gadmm::metrics::Trace;
use gadmm::model::Problem;
use gadmm::optim::{self, RunOptions};
use gadmm::session::AlgoSpec;
use gadmm::topology::UnitCosts;
use gadmm::util::rng::Pcg64;

fn linreg_problem(workers: usize, seed: u64) -> Problem {
    let ds = synthetic::linreg(120, 8, &mut Pcg64::seeded(seed));
    Problem::from_dataset(&ds, workers)
}

fn logreg_problem(workers: usize, seed: u64) -> Problem {
    let ds = synthetic::logreg(120, 6, &mut Pcg64::seeded(seed));
    Problem::from_dataset(&ds, workers)
}

/// Run `spec` at the given execution width (same problem, same seed).
fn run_at(spec: AlgoSpec, width: usize, problem: &Problem, opts: &RunOptions) -> Trace {
    let mut engine = spec.with_threads(width).build(problem, 11);
    optim::run(&mut *engine, problem, &UnitCosts, opts)
}

/// The six group engines at a chain-legal worker count, `rho` tuned to
/// the task's curvature regime.
fn six_engines(rho: f64) -> Vec<AlgoSpec> {
    vec![
        AlgoSpec::parse(&format!("gadmm:rho={rho}")).unwrap(),
        AlgoSpec::parse(&format!("qgadmm:rho={rho},bits=8")).unwrap(),
        AlgoSpec::parse(&format!("cgadmm:rho={rho},tau=1,mu=0.93")).unwrap(),
        AlgoSpec::parse(&format!("cqgadmm:rho={rho},bits=8,tau=1,mu=0.93")).unwrap(),
        AlgoSpec::parse(&format!("dgadmm:rho={rho},tau=15,mode=free")).unwrap(),
        AlgoSpec::parse(&format!("ggadmm:rho={rho},graph=chain")).unwrap(),
    ]
}

#[test]
fn pool_is_bit_identical_on_the_paper_linreg_config() {
    let problem = linreg_problem(6, 1);
    let opts = RunOptions::with_target(1e-4, 6_000);
    let mut converged = 0usize;
    for spec in six_engines(5.0) {
        let serial = run_at(spec, 1, &problem, &opts);
        assert!(!serial.records.is_empty(), "{spec}: serial run produced no records");
        converged += usize::from(serial.iters_to_target().is_some());
        for width in [2usize, 4] {
            let pooled = run_at(spec, width, &problem, &opts);
            assert!(
                serial.same_path(&pooled),
                "{spec} diverged between serial and threads={width} on linreg"
            );
        }
    }
    // The pin is meaningful: the static-chain engines all reach the
    // paper's target on this config (D-GADMM's re-chain schedule may
    // legitimately need more headroom at this ρ).
    assert!(converged >= 5, "only {converged}/6 engines converged");
}

#[test]
fn pool_is_bit_identical_on_the_paper_logreg_config() {
    // Logistic subproblems run damped Newton with a per-worker Hessian
    // cache — the compute-heavy path the pool exists for — so this pin
    // also proves the cache state evolves identically under parallelism.
    // The cache is stateful *across* runs (its reuse heuristic reads the
    // previous run's anchor), so each width gets a fresh problem: the pin
    // must isolate the execution backend, not cache carryover.
    let opts = RunOptions::with_target(1e-3, 4_000);
    for spec in six_engines(0.3) {
        let serial = run_at(spec, 1, &logreg_problem(4, 2), &opts);
        let pooled = run_at(spec, 4, &logreg_problem(4, 2), &opts);
        assert!(
            serial.same_path(&pooled),
            "{spec} diverged between serial and threads=4 on logreg"
        );
    }
}

#[test]
fn pool_is_bit_identical_on_non_chain_graphs_and_odd_n() {
    // The general-graph phase path (per-edge duals, degree > 2, odd
    // worker counts a chain cannot express).
    let problem = linreg_problem(7, 3);
    let opts = RunOptions::with_target(1e-4, 10_000);
    for graph in ["star", "complete", "rgg:radius=5"] {
        let spec = AlgoSpec::parse(&format!("ggadmm:rho=5,graph={graph}")).unwrap();
        let serial = run_at(spec, 1, &problem, &opts);
        let pooled = run_at(spec, 3, &problem, &opts);
        assert!(serial.same_path(&pooled), "ggadmm on {graph} diverged under the pool");
    }
}

#[test]
fn randomized_configs_are_invariant_across_widths_1_2_4() {
    // Property pin: random engine/ρ/worker-count/seed draws, each run at
    // widths 1, 2, and 4 — all three traces must be the same path.
    let mut rng = Pcg64::seeded(0xeec);
    for case in 0..6 {
        let workers = if rng.range(0, 2) == 0 { 4 } else { 6 };
        let problem = linreg_problem(workers, 100 + case);
        let rho = 1.0 + rng.range(0, 5) as f64;
        let specs = six_engines(rho);
        let spec = specs[rng.range(0, specs.len())];
        let opts = RunOptions::with_target(1e-3, 2_000);
        let serial = run_at(spec, 1, &problem, &opts);
        let two = run_at(spec, 2, &problem, &opts);
        let four = run_at(spec, 4, &problem, &opts);
        assert!(serial.same_path(&two), "case {case}: {spec} at width 2");
        assert!(serial.same_path(&four), "case {case}: {spec} at width 4");
    }
}

#[test]
fn sgadmm_is_bit_identical_across_widths_1_2_4() {
    // S-GADMM's stochastic prox adds per-worker mutable state (anchor,
    // call counter, minibatch scratch) to the pooled phase tasks. The
    // state is owned per worker — never per lane — and the sampler is a
    // pure function of (seed, worker, draw), so width must stay a pure
    // wall-clock knob for the stochastic engine too. batch 8 < m_s = 20
    // keeps the SVRG path (not the degenerate exact-prox delegation) on
    // every worker.
    let problem = linreg_problem(6, 5);
    let opts = RunOptions::with_target(1e-4, 2_000);
    let spec = AlgoSpec::parse("sgadmm:rho=5,batch=8,epochs=1").unwrap();
    let serial = run_at(spec, 1, &problem, &opts);
    assert!(!serial.records.is_empty(), "sgadmm serial run produced no records");
    for width in [2usize, 4] {
        let pooled = run_at(spec, width, &problem, &opts);
        assert!(
            serial.same_path(&pooled),
            "sgadmm diverged between serial and threads={width}"
        );
    }
}

#[test]
fn width_does_not_change_engine_names_or_seeds() {
    // The knob must be invisible everywhere results are keyed: engine
    // display names (trace identity) and sweep cell engine seeds.
    let problem = linreg_problem(4, 4);
    for spec in six_engines(3.0) {
        let serial = spec.build(&problem, 7).name();
        let pooled = spec.with_threads(4).build(&problem, 7).name();
        assert_eq!(serial, pooled, "engine name must not encode the execution width");
    }
}
