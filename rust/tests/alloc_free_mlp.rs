//! Allocation-freedom regression test for the block-structured hot path.
//!
//! Sibling of `alloc_free.rs` (same counting `#[global_allocator]`, same
//! single-`#[test]`-per-binary rule) covering the L-FGADMM tentpole: the
//! MLP prox solves run in the per-worker reusable workspace, the layer
//! schedule rewrites per-layer chunks into each link's `MsgBuf` in place,
//! and the receivers' assembled views are fixed buffers — so after a
//! warmup that primes every lazily-sized structure (prox GD scratch, the
//! layered `MsgBuf` high-water mark at iteration 0, the meter's uplink
//! table), ten further steady-state iterations must perform **zero** heap
//! allocations. See `docs/adr/009-block-layout-lfgadmm.md`.
//!
//! The schedule below mixes period-1 and period-2 layers deliberately:
//! steady state alternates full-transmit and partial-transmit rounds, so
//! the pin covers both the chunk-reuse path and the stale-layer path.

use gadmm::comm::Meter;
use gadmm::model::mlp_problem;
use gadmm::optim::{Engine, Lfgadmm};
use gadmm::topology::UnitCosts;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

/// System allocator with an allocation-event counter (see `alloc_free.rs`).
struct CountingAlloc;

static ALLOCATIONS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn steady_state_lfgadmm_mlp_iteration_is_allocation_free() {
    let problem = mlp_problem(240, 4, 1);
    // Per-tensor blocks with a mixed schedule: the big input layer stales
    // every other round, the rest travel every round.
    let mut engine = Lfgadmm::on_problem_layout(&problem, 0.5, vec![2, 1, 1, 1]);
    let costs = UnitCosts;
    let mut meter = Meter::new(&costs);

    // Warmup: iteration 0 transmits every layer (the layered MsgBuf
    // high-water mark), the first prox solves size the GD workspaces, and
    // the meter grows its per-worker tables. Construction *should*
    // allocate — a zero count here would mean the counter isn't installed.
    for k in 0..50 {
        engine.step(k, &mut meter);
    }
    assert!(
        ALLOCATIONS.load(Ordering::SeqCst) > 0,
        "counting allocator saw no allocations at all — wrapper not installed?"
    );

    let before = ALLOCATIONS.load(Ordering::SeqCst);
    for k in 50..60 {
        engine.step(k, &mut meter);
    }
    let after = ALLOCATIONS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "steady-state L-FGADMM/MLP iterations allocated {} time(s) in 10 steps — \
         the block-structured allocation-free hot path regressed",
        after - before
    );

    // The ten audited steps did real work on a live nonconvex objective.
    assert!(engine.objective().is_finite());
}
