//! Refactor-equivalence pin for the `GroupAdmmCore` unification.
//!
//! The `legacy` module below is a *frozen, verbatim copy* of the
//! pre-refactor engines' iteration logic (`optim/gadmm.rs`,
//! `optim/qgadmm.rs`, `optim/dgadmm.rs` at commit d17f99f, trimmed to the
//! code paths their default configurations execute). Every test runs a
//! legacy engine and its post-refactor counterpart on the same problem and
//! asserts `Trace::same_path` — bitwise-identical measurements at every
//! recorded iteration, identical convergence points, identical TC/bits
//! accounting. This is the contract that lets `Gadmm`, `Qgadmm`, and
//! `Dgadmm` become thin configurations of the policy-parameterized core
//! without any behavioural drift.

use gadmm::comm::{Compressor, Meter, StochasticQuantizer};
use gadmm::config::DatasetKind;
use gadmm::data::synthetic;
use gadmm::linalg::{vector as vec_ops, BlockLayout};
use gadmm::model::{mlp_problem, Problem};
use gadmm::optim::{run, Dgadmm, Engine, Gadmm, Lfgadmm, Qgadmm, RechainMode, RunOptions};
use gadmm::topology::chain::{self, Chain};
use gadmm::topology::{EnergyCostModel, LinkCosts, Placement, UnitCosts};
use gadmm::util::rng::Pcg64;

/// Frozen pre-refactor engines (commit d17f99f). Do not "improve" this
/// code — its whole value is that it does not change.
mod legacy {
    use super::*;

    pub struct LegacyGadmm<'a> {
        problem: &'a Problem,
        pub rho: f64,
        rho_eff: f64,
        chain: Chain,
        theta: Vec<Vec<f64>>,
        lambda: Vec<Vec<f64>>,
        q: Vec<f64>,
    }

    impl<'a> LegacyGadmm<'a> {
        pub fn new(problem: &'a Problem, rho: f64) -> LegacyGadmm<'a> {
            LegacyGadmm::with_chain(problem, rho, Chain::sequential(problem.num_workers()))
        }

        pub fn with_chain(problem: &'a Problem, rho: f64, chain: Chain) -> LegacyGadmm<'a> {
            let n = problem.num_workers();
            assert_eq!(chain.len(), n);
            assert!(n >= 2 && n % 2 == 0, "GADMM requires an even N ≥ 2");
            assert!(rho > 0.0);
            let d = problem.dim;
            LegacyGadmm {
                problem,
                rho,
                rho_eff: rho * problem.data_weight,
                chain,
                theta: vec![vec![0.0; d]; n],
                lambda: vec![vec![0.0; d]; n],
                q: vec![0.0; d],
            }
        }

        pub fn chain(&self) -> &Chain {
            &self.chain
        }

        pub fn set_chain(&mut self, chain: Chain) {
            assert_eq!(chain.len(), self.chain.len());
            self.chain = chain;
        }

        pub fn reinit_duals_for_chain(&mut self) {
            let feas = self.feasible_duals();
            for (w, f) in feas.into_iter().enumerate() {
                self.lambda[w] = f;
            }
        }

        pub fn feasible_duals(&self) -> Vec<Vec<f64>> {
            let n = self.chain.len();
            let d = self.problem.dim;
            let mut out = vec![vec![0.0; d]; n];
            let mut running = vec![0.0; d];
            let mut g = vec![0.0; d];
            for p in 0..n - 1 {
                let w = self.chain.order[p];
                self.problem.losses[w].grad_into(&self.theta[w], &mut g);
                for j in 0..d {
                    running[j] -= g[j];
                }
                out[w].copy_from_slice(&running);
            }
            out
        }

        fn update_position(&mut self, p: usize) {
            let n = self.chain.len();
            let w = self.chain.order[p];
            let d = self.problem.dim;
            self.q.iter_mut().for_each(|x| *x = 0.0);
            let mut couplings = 0.0;
            if p > 0 {
                let left = self.chain.order[p - 1];
                for j in 0..d {
                    self.q[j] += -self.lambda[left][j] - self.rho_eff * self.theta[left][j];
                }
                couplings += 1.0;
            }
            if p + 1 < n {
                let right = self.chain.order[p + 1];
                for j in 0..d {
                    self.q[j] += self.lambda[w][j] - self.rho_eff * self.theta[right][j];
                }
                couplings += 1.0;
            }
            let c = self.rho_eff * couplings;
            self.theta[w] = self.problem.losses[w].prox_argmin(&self.q, c, &self.theta[w]);
        }

        fn meter_phase(&self, meter: &mut Meter, head_phase: bool) {
            meter.begin_round();
            let n = self.chain.len();
            let start = if head_phase { 0 } else { 1 };
            for p in (start..n).step_by(2) {
                let w = self.chain.order[p];
                let (l, r) = self.chain.neighbors(p);
                let neigh: Vec<usize> = [l, r].into_iter().flatten().collect();
                meter.neighbor_broadcast(w, &neigh);
            }
        }
    }

    impl Engine for LegacyGadmm<'_> {
        fn name(&self) -> String {
            format!("GADMM(rho={})", self.rho)
        }

        fn step(&mut self, _k: usize, meter: &mut Meter) {
            let n = self.chain.len();
            for p in (0..n).step_by(2) {
                self.update_position(p);
            }
            self.meter_phase(meter, true);
            for p in (1..n).step_by(2) {
                self.update_position(p);
            }
            self.meter_phase(meter, false);
            for p in 0..n - 1 {
                let (a, b) = (self.chain.order[p], self.chain.order[p + 1]);
                for j in 0..self.problem.dim {
                    self.lambda[a][j] += self.rho_eff * (self.theta[a][j] - self.theta[b][j]);
                }
            }
        }

        fn objective(&self) -> f64 {
            self.problem.objective_per_worker(&self.theta)
        }

        fn acv(&self) -> f64 {
            let n = self.chain.len();
            let mut total = 0.0;
            for p in 0..n - 1 {
                let (a, b) = (self.chain.order[p], self.chain.order[p + 1]);
                total += vec_ops::norm1(&vec_ops::sub(&self.theta[a], &self.theta[b]));
            }
            total / n as f64
        }
    }

    pub struct LegacyQgadmm<'a> {
        problem: &'a Problem,
        pub rho: f64,
        rho_eff: f64,
        chain: Chain,
        theta: Vec<Vec<f64>>,
        hat: Vec<Vec<f64>>,
        lambda: Vec<Vec<f64>>,
        quantizers: Vec<StochasticQuantizer>,
        bits: u32,
        q: Vec<f64>,
    }

    impl<'a> LegacyQgadmm<'a> {
        pub fn new(problem: &'a Problem, rho: f64, bits: u32, seed: u64) -> LegacyQgadmm<'a> {
            let n = problem.num_workers();
            let chain = Chain::sequential(n);
            assert!(n >= 2 && n % 2 == 0, "GADMM requires an even N ≥ 2");
            assert!(rho > 0.0);
            let d = problem.dim;
            let quantizers = (0..n)
                .map(|w| StochasticQuantizer::for_worker(d, bits, seed, w))
                .collect();
            LegacyQgadmm {
                problem,
                rho,
                rho_eff: rho * problem.data_weight,
                chain,
                theta: vec![vec![0.0; d]; n],
                hat: vec![vec![0.0; d]; n],
                lambda: vec![vec![0.0; d]; n],
                quantizers,
                bits,
                q: vec![0.0; d],
            }
        }

        pub fn message_bits(&self) -> f64 {
            self.quantizers[0].message_bits()
        }

        fn update_position(&mut self, p: usize) {
            let n = self.chain.len();
            let w = self.chain.order[p];
            let d = self.problem.dim;
            self.q.iter_mut().for_each(|x| *x = 0.0);
            let mut couplings = 0.0;
            if p > 0 {
                let left = self.chain.order[p - 1];
                for j in 0..d {
                    self.q[j] += -self.lambda[left][j] - self.rho_eff * self.hat[left][j];
                }
                couplings += 1.0;
            }
            if p + 1 < n {
                let right = self.chain.order[p + 1];
                for j in 0..d {
                    self.q[j] += self.lambda[w][j] - self.rho_eff * self.hat[right][j];
                }
                couplings += 1.0;
            }
            let c = self.rho_eff * couplings;
            self.theta[w] = self.problem.losses[w].prox_argmin(&self.q, c, &self.theta[w]);
            let _msg = self.quantizers[w].encode(&self.theta[w]);
            self.hat[w].copy_from_slice(self.quantizers[w].public_view());
        }

        fn meter_phase(&self, meter: &mut Meter, head_phase: bool) {
            meter.begin_round();
            let n = self.chain.len();
            let bits = self.message_bits();
            let start = usize::from(!head_phase);
            for p in (start..n).step_by(2) {
                let w = self.chain.order[p];
                let (l, r) = self.chain.neighbors(p);
                let neigh: Vec<usize> = [l, r].into_iter().flatten().collect();
                meter.neighbor_broadcast_bits(w, &neigh, bits);
            }
        }
    }

    impl Engine for LegacyQgadmm<'_> {
        fn name(&self) -> String {
            format!("Q-GADMM(rho={},b={})", self.rho, self.bits)
        }

        fn step(&mut self, _k: usize, meter: &mut Meter) {
            let n = self.chain.len();
            for p in (0..n).step_by(2) {
                self.update_position(p);
            }
            self.meter_phase(meter, true);
            for p in (1..n).step_by(2) {
                self.update_position(p);
            }
            self.meter_phase(meter, false);
            for p in 0..n - 1 {
                let (a, b) = (self.chain.order[p], self.chain.order[p + 1]);
                for j in 0..self.problem.dim {
                    self.lambda[a][j] += self.rho_eff * (self.hat[a][j] - self.hat[b][j]);
                }
            }
        }

        fn objective(&self) -> f64 {
            self.problem.objective_per_worker(&self.theta)
        }

        fn acv(&self) -> f64 {
            let n = self.chain.len();
            let mut total = 0.0;
            for p in 0..n - 1 {
                let (a, b) = (self.chain.order[p], self.chain.order[p + 1]);
                total += vec_ops::norm1(&vec_ops::sub(&self.theta[a], &self.theta[b]));
            }
            total / n as f64
        }
    }

    const STALL_WINDOW: usize = 150;

    /// Legacy D-GADMM, default `DualHandling::Reuse` paths only (the
    /// configuration the spec registry builds).
    pub struct LegacyDgadmm<'a> {
        inner: LegacyGadmm<'a>,
        pub tau: usize,
        pub mode: RechainMode,
        costs: &'a dyn LinkCosts,
        rng: Pcg64,
        build_pending: usize,
        acv_best: f64,
        last_improve: usize,
        frozen: bool,
        work_iters: usize,
    }

    impl<'a> LegacyDgadmm<'a> {
        pub fn new(
            problem: &'a Problem,
            rho: f64,
            tau: usize,
            mode: RechainMode,
            costs: &'a dyn LinkCosts,
            seed: u64,
        ) -> LegacyDgadmm<'a> {
            assert!(tau >= 1);
            let mut rng = Pcg64::new(seed, 0xd6ad);
            let initial = chain::rechain(problem.num_workers(), costs, &mut rng);
            LegacyDgadmm {
                inner: LegacyGadmm::with_chain(problem, rho, initial),
                tau,
                mode,
                costs,
                rng,
                build_pending: 0,
                acv_best: f64::INFINITY,
                last_improve: 0,
                frozen: false,
                work_iters: 0,
            }
        }

        fn rechain_now(&mut self, meter: &mut Meter) {
            let n = self.inner.chain().len();
            let new_chain = chain::rechain(n, self.costs, &mut self.rng);
            match self.mode {
                RechainMode::Free => {
                    self.inner.set_chain(new_chain);
                }
                RechainMode::Announced => {
                    meter.begin_round();
                    meter.begin_round();
                    self.inner.set_chain(new_chain);
                    let order = self.inner.chain().order.clone();
                    meter.begin_round();
                    for p in (0..n).step_by(2) {
                        let (l, r) = self.inner.chain().neighbors(p);
                        let neigh: Vec<usize> = [l, r].into_iter().flatten().collect();
                        meter.neighbor_broadcast(order[p], &neigh);
                    }
                    meter.begin_round();
                    for p in (1..n).step_by(2) {
                        let (l, r) = self.inner.chain().neighbors(p);
                        let neigh: Vec<usize> = [l, r].into_iter().flatten().collect();
                        meter.neighbor_broadcast(order[p], &neigh);
                    }
                    self.build_pending = 2;
                }
            }
        }
    }

    impl Engine for LegacyDgadmm<'_> {
        fn name(&self) -> String {
            format!(
                "D-GADMM(rho={},tau={},{})",
                self.inner.rho,
                self.tau,
                match self.mode {
                    RechainMode::Announced => "announced",
                    RechainMode::Free => "free",
                }
            )
        }

        fn step(&mut self, k: usize, meter: &mut Meter) {
            if self.build_pending > 0 {
                self.build_pending -= 1;
                return;
            }
            if k > 0 && k % self.tau == 0 && !self.frozen {
                self.rechain_now(meter);
                if self.build_pending > 0 {
                    self.build_pending -= 1;
                    return;
                }
            }
            self.inner.step(self.work_iters, meter);
            self.work_iters += 1;
            let acv = self.inner.acv();
            if acv < 0.9 * self.acv_best {
                self.acv_best = acv;
                self.last_improve = self.work_iters;
            } else if !self.frozen && self.work_iters - self.last_improve > STALL_WINDOW {
                self.frozen = true;
                self.inner.reinit_duals_for_chain();
            }
        }

        fn objective(&self) -> f64 {
            self.inner.objective()
        }

        fn acv(&self) -> f64 {
            self.inner.acv()
        }
    }
}

#[test]
fn gadmm_paper_linreg_trace_is_bit_identical_to_legacy() {
    // The paper's synthetic linreg config (1200×50) at N=6.
    let ds = DatasetKind::SyntheticLinreg.build(1);
    let p = Problem::from_dataset(&ds, 6);
    let opts = RunOptions::with_target(1e-3, 20_000);
    let costs = UnitCosts;
    let new = run(&mut Gadmm::new(&p, 5.0), &p, &costs, &opts);
    let old = run(&mut legacy::LegacyGadmm::new(&p, 5.0), &p, &costs, &opts);
    assert!(new.same_path(&old), "post-refactor GADMM diverged from the frozen engine");
    assert!(new.iters_to_target().is_some());
}

#[test]
fn gadmm_small_linreg_and_permuted_chain_match_legacy() {
    let ds = synthetic::linreg(120, 8, &mut Pcg64::seeded(1));
    let p = Problem::from_dataset(&ds, 6);
    let opts = RunOptions::with_target(1e-8, 20_000);
    let costs = UnitCosts;
    let new = run(&mut Gadmm::new(&p, 5.0), &p, &costs, &opts);
    let old = run(&mut legacy::LegacyGadmm::new(&p, 5.0), &p, &costs, &opts);
    assert!(new.same_path(&old));

    let chain = Chain { order: vec![0, 3, 2, 4, 1, 5] };
    let new = run(&mut Gadmm::with_chain(&p, 2.0, chain.clone()), &p, &costs, &opts);
    let old = run(&mut legacy::LegacyGadmm::with_chain(&p, 2.0, chain), &p, &costs, &opts);
    assert!(new.same_path(&old), "permuted-chain GADMM diverged");
}

#[test]
fn gadmm_paper_logreg_trace_is_bit_identical_to_legacy() {
    let ds = synthetic::logreg(120, 6, &mut Pcg64::seeded(2));
    let p = Problem::from_dataset(&ds, 4);
    let opts = RunOptions::with_target(1e-4, 6_000);
    let costs = UnitCosts;
    let new = run(&mut Gadmm::new(&p, 0.3), &p, &costs, &opts);
    let old = run(&mut legacy::LegacyGadmm::new(&p, 0.3), &p, &costs, &opts);
    assert!(new.same_path(&old), "logreg GADMM diverged from the frozen engine");
    assert!(new.iters_to_target().is_some());
}

#[test]
fn qgadmm_paper_linreg_trace_is_bit_identical_to_legacy() {
    let ds = DatasetKind::SyntheticLinreg.build(1);
    let p = Problem::from_dataset(&ds, 6);
    let opts = RunOptions::with_target(1e-3, 20_000);
    let costs = UnitCosts;
    let new = run(&mut Qgadmm::new(&p, 5.0, 8, 1), &p, &costs, &opts);
    let old = run(&mut legacy::LegacyQgadmm::new(&p, 5.0, 8, 1), &p, &costs, &opts);
    assert!(new.same_path(&old), "post-refactor Q-GADMM diverged from the frozen engine");
    assert!(new.iters_to_target().is_some());
}

#[test]
fn qgadmm_logreg_trace_is_bit_identical_to_legacy() {
    let ds = synthetic::logreg(120, 6, &mut Pcg64::seeded(2));
    let p = Problem::from_dataset(&ds, 4);
    let opts = RunOptions::with_target(1e-4, 8_000);
    let costs = UnitCosts;
    let new = run(&mut Qgadmm::new(&p, 0.3, 8, 7), &p, &costs, &opts);
    let old = run(&mut legacy::LegacyQgadmm::new(&p, 0.3, 8, 7), &p, &costs, &opts);
    assert!(new.same_path(&old), "logreg Q-GADMM diverged from the frozen engine");
}

#[test]
fn dgadmm_free_rechain_trace_is_bit_identical_to_legacy() {
    let ds = synthetic::linreg(120, 8, &mut Pcg64::seeded(1));
    let p = Problem::from_dataset(&ds, 6);
    let opts = RunOptions::with_target(1e-4, 5_000);
    let costs = UnitCosts;
    let new = run(&mut Dgadmm::new(&p, 3.0, 1, RechainMode::Free, &costs, 42), &p, &costs, &opts);
    let old = run(
        &mut legacy::LegacyDgadmm::new(&p, 3.0, 1, RechainMode::Free, &costs, 42),
        &p,
        &costs,
        &opts,
    );
    assert!(new.same_path(&old), "free-mode D-GADMM diverged from the frozen engine");
    assert!(new.iters_to_target().is_some());
}

#[test]
fn dgadmm_announced_rechain_trace_is_bit_identical_to_legacy() {
    let ds = synthetic::linreg(120, 8, &mut Pcg64::seeded(2));
    let p = Problem::from_dataset(&ds, 6);
    let mut rng = Pcg64::seeded(7);
    let placement = Placement::random(6, 250.0, &mut rng);
    let energy = EnergyCostModel::new(&placement, placement.central_worker());
    let opts = RunOptions::with_target(1e-4, 8_000);
    let new = run(
        &mut Dgadmm::new(&p, 3.0, 15, RechainMode::Announced, &energy, 42),
        &p,
        &energy,
        &opts,
    );
    let old = run(
        &mut legacy::LegacyDgadmm::new(&p, 3.0, 15, RechainMode::Announced, &energy, 42),
        &p,
        &energy,
        &opts,
    );
    assert!(new.same_path(&old), "announced-mode D-GADMM diverged from the frozen engine");
}

/// Chain-degeneracy pin of the bipartite-graph generalization: the
/// `ggadmm:graph=chain` spec must take GADMM's exact path — bitwise
/// measurements, identical convergence point — on the paper's linreg and
/// logreg configs. Engine names differ by design ("GGADMM(rho=…,
/// graph=chain)" vs "GADMM(rho=…)"), so they are normalized before the
/// `Trace::same_path` comparison; every measured field must agree exactly.
fn assert_ggadmm_chain_matches_gadmm(p: &Problem, rho: f64, opts: &RunOptions) {
    let costs = UnitCosts;
    let mut g = run(&mut Gadmm::new(p, rho), p, &costs, opts);
    let spec = gadmm::session::AlgoSpec::parse(&format!("ggadmm:rho={rho},graph=chain"))
        .expect("valid ggadmm spec");
    let mut gg = run(&mut *spec.build(p, 1), p, &costs, opts);
    g.algorithm = "group-admm".into();
    gg.algorithm = "group-admm".into();
    assert!(gg.same_path(&g), "GGADMM(graph=chain) diverged from GADMM");
    assert!(gg.iters_to_target().is_some());
}

#[test]
fn ggadmm_chain_paper_linreg_trace_is_bit_identical_to_gadmm() {
    let ds = DatasetKind::SyntheticLinreg.build(1);
    let p = Problem::from_dataset(&ds, 6);
    assert_ggadmm_chain_matches_gadmm(&p, 5.0, &RunOptions::with_target(1e-3, 20_000));
}

#[test]
fn ggadmm_chain_paper_logreg_trace_is_bit_identical_to_gadmm() {
    let ds = synthetic::logreg(120, 6, &mut Pcg64::seeded(2));
    let p = Problem::from_dataset(&ds, 4);
    assert_ggadmm_chain_matches_gadmm(&p, 0.3, &RunOptions::with_target(1e-4, 6_000));
}

/// Whole-model degeneracy pin of the layer-wise generalization: an
/// `lfgadmm:` spec with a single full-width block at period 1 transmits
/// the entire model every round, so it must take GADMM's exact path —
/// bitwise measurements (including the bits column: one dense chunk of
/// `64·d` equals a dense broadcast) and the identical convergence point.
/// Engine names differ by design ("L-FGADMM(…)" vs "GADMM(…)"), so they
/// are normalized before the `Trace::same_path` comparison.
fn assert_lfgadmm_whole_model_matches_gadmm(p: &Problem, rho: f64, opts: &RunOptions) {
    let costs = UnitCosts;
    let mut g = run(&mut Gadmm::new(p, rho), p, &costs, opts);
    let spec =
        gadmm::session::AlgoSpec::parse(&format!("lfgadmm:rho={rho},layers={},periods=1", p.dim))
            .expect("valid lfgadmm spec");
    let mut lf = run(&mut *spec.build(p, 1), p, &costs, opts);
    g.algorithm = "group-admm".into();
    lf.algorithm = "group-admm".into();
    assert!(lf.same_path(&g), "L-FGADMM(single block, period 1) diverged from GADMM");
    assert!(lf.iters_to_target().is_some());
}

#[test]
fn lfgadmm_single_block_period1_linreg_trace_is_bit_identical_to_gadmm() {
    let ds = DatasetKind::SyntheticLinreg.build(1);
    let p = Problem::from_dataset(&ds, 6);
    assert_lfgadmm_whole_model_matches_gadmm(&p, 5.0, &RunOptions::with_target(1e-3, 20_000));
}

#[test]
fn lfgadmm_single_block_period1_logreg_trace_is_bit_identical_to_gadmm() {
    let ds = synthetic::logreg(120, 6, &mut Pcg64::seeded(2));
    let p = Problem::from_dataset(&ds, 4);
    assert_lfgadmm_whole_model_matches_gadmm(&p, 0.3, &RunOptions::with_target(1e-4, 6_000));
}

/// Block-structure degeneracy pin on the MLP: with every layer at
/// period 1 the per-tensor schedule transmits the whole model every
/// round, chunked — the same values land in the same receiver views, and
/// the layered bits (`Σ_ℓ 64·len_ℓ`) re-add to the blockless `64·d`. The
/// run must be `same_path`-identical to a single full-width block, so
/// the block decomposition itself provably changes nothing at period 1.
#[test]
fn lfgadmm_mlp_every_layer_period1_matches_blockless_reference() {
    let p = mlp_problem(240, 4, 1);
    let opts = RunOptions::with_target(1e-3, 600);
    let costs = UnitCosts;
    let mut blocked =
        run(&mut Lfgadmm::on_problem_layout(&p, 0.5, vec![1; 4]), &p, &costs, &opts);
    let mut flat = run(
        &mut Lfgadmm::new(&p, 0.5, BlockLayout::new(vec![p.dim]), vec![1]),
        &p,
        &costs,
        &opts,
    );
    blocked.algorithm = "group-admm".into();
    flat.algorithm = "group-admm".into();
    assert!(
        blocked.same_path(&flat),
        "per-tensor blocks at period 1 diverged from the blockless reference"
    );
    assert!(blocked.iters_to_target().is_some(), "MLP run missed the pin target");
}
