//! Offline shim of the `log` logging facade.
//!
//! Implements the subset of the real crate's API that this repository
//! uses: the five severity macros, [`Level`]/[`LevelFilter`], the [`Log`]
//! trait, and the global logger/max-level registry. Semantics match the
//! real facade: records above the max level are filtered before reaching
//! the logger, and `set_logger` succeeds exactly once.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Record severity, ordered from least verbose (`Error`) to most (`Trace`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

/// Verbosity ceiling, `Off` filtering everything.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        f.write_str(s)
    }
}

/// Metadata about a record: its level and target (module path).
#[derive(Clone, Debug)]
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log record: metadata plus the formatted message arguments.
#[derive(Clone)]
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// A log sink. Mirrors the real facade's trait.
pub trait Log: Sync + Send {
    fn enabled(&self, metadata: &Metadata<'_>) -> bool;
    fn log(&self, record: &Record<'_>);
    fn flush(&self);
}

/// Error returned when a logger is already installed.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("a logger is already installed")
    }
}

impl std::error::Error for SetLoggerError {}

static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Off as usize);

/// Install the global logger. Succeeds exactly once.
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// Set the global verbosity ceiling.
pub fn set_max_level(level: LevelFilter) {
    MAX_LEVEL.store(level as usize, Ordering::Relaxed);
}

/// Current global verbosity ceiling.
pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        5 => LevelFilter::Trace,
        _ => LevelFilter::Off,
    }
}

/// Macro plumbing — not public API. Dispatches one record to the installed
/// logger if the level passes the global filter.
#[doc(hidden)]
pub fn __dispatch(level: Level, target: &str, args: fmt::Arguments<'_>) {
    if level > max_level() {
        return;
    }
    if let Some(logger) = LOGGER.get() {
        let record = Record {
            metadata: Metadata { level, target },
            args,
        };
        if logger.enabled(record.metadata()) {
            logger.log(&record);
        }
    }
}

#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)+) => {
        $crate::__dispatch($lvl, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Error, $($arg)+) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Warn, $($arg)+) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Info, $($arg)+) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Debug, $($arg)+) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Trace, $($arg)+) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_orders_against_filter() {
        assert!(Level::Error <= LevelFilter::Error);
        assert!(Level::Info <= LevelFilter::Info);
        assert!(Level::Debug > LevelFilter::Info);
        assert!(Level::Error > LevelFilter::Off);
    }

    #[test]
    fn max_level_roundtrip() {
        set_max_level(LevelFilter::Debug);
        assert_eq!(max_level(), LevelFilter::Debug);
        set_max_level(LevelFilter::Off);
        assert_eq!(max_level(), LevelFilter::Off);
    }
}
