//! Offline shim of `anyhow`: a dynamic error type with context chains.
//!
//! Implements the subset this repository uses — [`Error`], the
//! [`Context`] extension trait, the [`anyhow!`]/[`bail!`] macros, and the
//! [`Result`] alias. Context chains render outermost-first; the alternate
//! format (`{:#}`) joins the chain with `: ` like the real crate.

use std::fmt;

/// Dynamic error: a chain of messages, outermost context first.
pub struct Error {
    /// `chain[0]` is the most recent context, the last entry the root cause.
    chain: Vec<String>,
}

impl Error {
    /// Build from a single message.
    pub fn msg(message: impl fmt::Display) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap with an outer context message.
    pub fn context(mut self, context: impl fmt::Display) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The root cause message (innermost entry of the chain).
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Error {
        // Preserve the source chain as context entries.
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>` with the usual overridable error parameter.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait attaching context to fallible results.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

/// Construct an [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)+) => {
        $crate::Error::msg(format!($($arg)+))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return Err($crate::anyhow!($($arg)+))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_shows_outermost_alternate_shows_chain() {
        let e: Error = Error::from(io_err()).context("loading manifest");
        assert_eq!(format!("{e}"), "loading manifest");
        assert_eq!(format!("{e:#}"), "loading manifest: missing file");
    }

    #[test]
    fn context_trait_wraps_results() {
        let r: Result<()> = Err(io_err()).context("outer");
        let e = r.unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: missing file");
        let r2: Result<()> = Err(io_err()).with_context(|| format!("ctx {}", 7));
        assert!(format!("{:#}", r2.unwrap_err()).starts_with("ctx 7: "));
    }

    #[test]
    fn macros_build_errors() {
        let e = anyhow!("bad value {}", 3);
        assert_eq!(format!("{e}"), "bad value 3");
        fn f() -> Result<()> {
            bail!("nope");
        }
        assert_eq!(format!("{}", f().unwrap_err()), "nope");
    }

    #[test]
    fn question_mark_converts() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(f().unwrap_err().root_cause(), "missing file");
    }
}
