//! Offline **stub** of the `xla` PJRT bindings.
//!
//! Type-checks `runtime::pjrt`/`runtime::service` without the native
//! `xla_extension` libraries. Every entry point that would touch the real
//! runtime returns [`Error::unavailable`], so the PJRT backend fails fast
//! with an actionable message while the rest of the crate (native backend,
//! coordinator, experiments) is fully functional. Swap this path
//! dependency for the real bindings in the root `Cargo.toml` to execute
//! the AOT artifacts; the API surface below matches what the repository
//! calls.

use std::fmt;
use std::path::Path;

/// Error type mirroring the bindings' string-carrying errors.
#[derive(Debug, Clone)]
pub struct Error {
    message: String,
}

impl Error {
    fn unavailable(what: &str) -> Error {
        Error {
            message: format!(
                "{what}: PJRT runtime unavailable — this build links the offline `xla` stub \
                 (rust/vendor/xla); swap in the real xla bindings to execute AOT artifacts"
            ),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.message)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Host-side literal (tensor) handle. The stub carries no data.
#[derive(Clone, Debug, Default)]
pub struct Literal(());

impl Literal {
    /// Rank-1 f64 literal.
    pub fn vec1(_values: &[f64]) -> Literal {
        Literal(())
    }

    /// Rank-0 f64 literal.
    pub fn scalar(_value: f64) -> Literal {
        Literal(())
    }

    /// Reinterpret with new dimensions.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal(()))
    }

    /// Unwrap a 1-tuple result literal.
    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(Error::unavailable("Literal::to_tuple1"))
    }

    /// Copy out as a host vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("Literal::to_vec"))
    }
}

/// Parsed HLO module handle.
#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(path: &Path) -> Result<HloModuleProto> {
        Err(Error::unavailable(&format!(
            "parsing HLO text {}",
            path.display()
        )))
    }
}

/// Computation handle built from a proto.
#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Device-resident buffer returned by an execution.
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    /// Execute with the given argument literals.
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client handle.
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    /// Create the CPU client. Always fails in the stub.
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(format!("{err}").contains("stub"));
    }

    #[test]
    fn literal_constructors_are_usable() {
        let l = Literal::vec1(&[1.0, 2.0]).reshape(&[2, 1]).unwrap();
        assert!(l.to_vec::<f64>().is_err());
        let s = Literal::scalar(3.0);
        assert!(s.to_tuple1().is_err());
    }

    #[test]
    fn hlo_parse_is_unavailable() {
        assert!(HloModuleProto::from_text_file(Path::new("/tmp/x.hlo.txt")).is_err());
    }
}
