//! Fig-2-style scenario: compare GADMM against the centralized baselines
//! on the paper's synthetic linear-regression task (1200×50, N=24) and
//! print the iteration/TC summary — the numbers behind the paper's
//! headline claim.
//!
//!     cargo run --release --example linreg_chain [-- --workers 24]

use gadmm::data::synthetic;
use gadmm::model::Problem;
use gadmm::optim::{run, Gadmm, Gd, Lag, LagVariant, RunOptions};
use gadmm::topology::UnitCosts;
use gadmm::util::cli::Args;
use gadmm::util::table::{fmt_count, Table};

fn main() {
    gadmm::util::logging::init();
    let args = Args::from_env(&[]).expect("args");
    let n = args.get_usize("workers", 24).expect("workers");
    let rhos = args.get_f64_list("rho", &[3.0, 5.0, 7.0]).expect("rho");

    let dataset = synthetic::linreg_default(1);
    let problem = Problem::from_dataset(&dataset, n);
    let opts = RunOptions::with_target(1e-4, 300_000);
    let costs = UnitCosts;

    let mut traces = Vec::new();
    for rho in rhos {
        traces.push(run(&mut Gadmm::new(&problem, rho), &problem, &costs, &opts));
    }
    traces.push(run(&mut Gd::new(&problem), &problem, &costs, &opts));
    traces.push(run(&mut Lag::new(&problem, LagVariant::Wk), &problem, &costs, &opts));
    traces.push(run(&mut Lag::new(&problem, LagVariant::Ps), &problem, &costs, &opts));

    let mut table = Table::new(vec!["Algorithm", "iterations", "TC", "time (ms)"]);
    for t in &traces {
        table.row(vec![
            t.algorithm.clone(),
            t.iters_to_target().map(fmt_count).unwrap_or_else(|| "—".into()),
            t.tc_to_target().map(|c| fmt_count(c as usize)).unwrap_or_else(|| "—".into()),
            t.time_to_target()
                .map(|d| format!("{:.1}", d.as_secs_f64() * 1e3))
                .unwrap_or_else(|| "—".into()),
        ]);
    }
    println!("synthetic linreg 1200×50, N={n}, target 1e-4\n{}", table.render());
}
