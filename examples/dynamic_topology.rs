//! D-GADMM scenario: 50 workers move around a 250×250 m² area every 15
//! iterations (the paper's Fig-7 setting). Static GADMM keeps its initial
//! logical chain and pays ever-worse radio energy; D-GADMM rebuilds the
//! chain with the Appendix-D heuristic at every coherence interval.
//!
//!     cargo run --release --example dynamic_topology [-- --workers 50 --tau 15]

use gadmm::experiments::fig7;
use gadmm::util::cli::Args;

fn main() {
    gadmm::util::logging::init();
    let args = Args::from_env(&[]).expect("args");
    let n = args.get_usize("workers", 50).expect("workers");
    let tau = args.get_usize("tau", 15).expect("tau");

    let out = fig7::run(n, 3.0, tau, 1e-4, 100_000, 2);
    println!("time-varying topology (N={n}, coherence τ={tau}):");
    for (label, t) in [("GADMM (frozen chain)", &out.gadmm), ("D-GADMM (re-chains)", &out.dgadmm)] {
        println!(
            "  {label:<22} iterations {:?}, energy TC {}",
            t.iters_to_target(),
            t.energy_to_target()
                .map(|e| format!("{e:.3e} J"))
                .unwrap_or_else(|| "—".into())
        );
    }
    let (g, d) = (out.gadmm.energy_to_target(), out.dgadmm.energy_to_target());
    if let (Some(g), Some(d)) = (g, d) {
        println!("  → D-GADMM used {:.1}× less transmit energy", g / d);
    }
}
