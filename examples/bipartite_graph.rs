//! Bipartite-graph topologies: run GGADMM — the generalized group ADMM —
//! on a chain, a star, a random geometric graph, and complete bipartite
//! coupling over the same sharded problem, and compare how average degree
//! trades iterations against per-slot energy.
//!
//!     cargo run --release --example bipartite_graph

use gadmm::data::synthetic;
use gadmm::model::Problem;
use gadmm::optim::{run, Ggadmm, RunOptions};
use gadmm::topology::graph::{BipartiteGraph, GraphKind};
use gadmm::topology::{EnergyCostModel, Placement};
use gadmm::util::rng::Pcg64;

fn main() {
    gadmm::util::logging::init();

    // 700 samples, 12 features, split evenly across 14 workers, with a
    // physical placement in the paper's 10×10 m² area.
    let dataset = synthetic::linreg(700, 12, &mut Pcg64::seeded(7));
    let workers = 14;
    let problem = Problem::from_dataset(&dataset, workers);
    let placement = Placement::random(workers, 10.0, &mut Pcg64::seeded(99));
    let costs = EnergyCostModel::new(&placement, placement.central_worker());
    println!("problem: {} (F* = {:.6e})", problem.name, problem.f_star);

    // A graph is data: explicit head/tail sets + validated edges. The
    // generators cover the common shapes; `BipartiteGraph::new` accepts
    // any connected head↔tail edge list you can dream up.
    let rgg = BipartiteGraph::random_geometric(&placement, 3.5).expect("connected by stitching");
    println!(
        "rgg(3.5): {} edges over {} heads + {} tails (avg degree {:.2})",
        rgg.num_edges(),
        rgg.heads().len(),
        rgg.tails().len(),
        rgg.avg_degree()
    );

    let opts = RunOptions::with_target(1e-4, 50_000);
    for kind in [
        GraphKind::Chain,
        GraphKind::Star,
        GraphKind::Rgg { radius: 3.5 },
        GraphKind::Complete,
    ] {
        let mut engine =
            Ggadmm::with_placement(&problem, 3.0, kind, &placement).expect("valid topology");
        let degree = engine.graph().avg_degree();
        let trace = run(&mut engine, &problem, &costs, &opts);
        match trace.iters_to_target() {
            Some(k) => println!(
                "{:<16} avg degree {degree:>5.2} | {k:>5} iters | TC {:>6.0} | energy {:.3e}",
                kind.to_string(),
                trace.tc_to_target().unwrap(),
                trace.energy_to_target().unwrap()
            ),
            None => println!(
                "{:<16} avg degree {degree:>5.2} | did not converge (err {:.3e})",
                kind.to_string(),
                trace.final_error()
            ),
        }
    }
    println!("every topology pays N slots/iteration — degree buys mixing speed, not slots");
}
