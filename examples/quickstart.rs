//! Quickstart: solve a distributed linear-regression problem with GADMM in
//! a dozen lines — build a dataset, shard it over 8 workers, run Algorithm
//! 1, and inspect the paper's metrics.
//!
//!     cargo run --release --example quickstart

use gadmm::data::synthetic;
use gadmm::model::Problem;
use gadmm::optim::{run, Gadmm, RunOptions};
use gadmm::topology::UnitCosts;
use gadmm::util::rng::Pcg64;

fn main() {
    gadmm::util::logging::init();

    // 600 samples, 20 features, split evenly across 8 workers.
    let dataset = synthetic::linreg(600, 20, &mut Pcg64::seeded(7));
    let problem = Problem::from_dataset(&dataset, 8);
    println!("problem: {} (F* = {:.6e})", problem.name, problem.f_star);

    // GADMM with ρ = 3 until the paper's 1e−4 objective error.
    let mut engine = Gadmm::new(&problem, 3.0);
    let trace = run(&mut engine, &problem, &UnitCosts, &RunOptions::with_target(1e-4, 50_000));

    match trace.iters_to_target() {
        Some(k) => println!(
            "converged in {k} iterations — total communication cost {} (= {k} × N transmissions)",
            trace.tc_to_target().unwrap()
        ),
        None => println!("did not converge: final error {:.3e}", trace.final_error()),
    }
    // Every worker ends at (nearly) the same model:
    let consensus = engine.consensus_mean();
    let dist = gadmm::linalg::vector::dist2(&consensus, &problem.theta_star);
    println!("‖consensus − θ*‖ = {dist:.3e}, final ACV = {:.3e}",
        trace.records.last().map(|r| r.acv).unwrap_or(f64::NAN));
}
