//! END-TO-END DRIVER — proves all three layers compose on a real workload.
//!
//! Loads the AOT-compiled JAX+Pallas artifacts (`make artifacts`) through
//! the PJRT runtime, spins up the distributed coordinator (one thread per
//! worker + a device-service thread owning the PJRT client), and trains:
//!
//!   1. linear regression, synthetic 1200×50, N=24 workers (paper Fig. 2)
//!   2. logistic regression, synthetic 1200×50, N=4 workers (paper Fig. 6c)
//!
//! Both runs log their loss curves, verify convergence to the paper's 1e−4
//! objective error, and cross-check the PJRT result against the native
//! backend. Recorded in EXPERIMENTS.md §E2E.
//!
//!     make artifacts && cargo run --release --example e2e_train

use gadmm::config::DatasetKind;
use gadmm::coordinator;
use gadmm::data::partition_even;
use gadmm::model::Problem;
use gadmm::optim::RunOptions;
use gadmm::runtime::{artifacts_dir, service::PjrtService, Manifest, NativeSolver};
use gadmm::topology::chain::Chain;
use gadmm::topology::UnitCosts;

fn main() {
    gadmm::util::logging::init();
    let manifest = match Manifest::load(&artifacts_dir()) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("e2e_train needs the AOT artifacts: {e}\nrun `make artifacts` first");
            std::process::exit(2);
        }
    };

    let runs = [
        (DatasetKind::SyntheticLinreg, 24usize, 3.0, "linear regression (Fig. 2 workload)"),
        (DatasetKind::SyntheticLogreg, 4usize, 0.3, "logistic regression (Fig. 6c workload)"),
    ];
    let costs = UnitCosts;
    let mut all_ok = true;

    for (kind, n, rho, label) in runs {
        println!("\n=== e2e: {label} — N={n}, rho={rho}, backend=PJRT ===");
        let ds = kind.build(1);
        let problem = Problem::from_dataset(&ds, n);
        let shards = partition_even(&ds, n);
        let service = PjrtService::spawn(
            manifest.clone(),
            kind.task(),
            shards,
            problem.logreg_mu,
            problem.data_weight,
        )
        .expect("PJRT service");
        let opts = RunOptions::with_target(1e-4, 5_000);
        let t0 = std::time::Instant::now();
        let result = coordinator::train(
            &problem,
            service.solvers(),
            rho,
            Chain::sequential(n),
            &costs,
            &opts,
        );
        let wall = t0.elapsed();

        // Loss curve (log-spaced samples).
        println!("  loss curve (objective error vs iteration):");
        for r in result.trace.downsample(12) {
            println!("    iter {:>6}  obj_err {:.6e}  acv {:.3e}", r.iter, r.obj_err, r.acv);
        }
        match result.trace.iters_to_target() {
            Some(k) => println!(
                "  CONVERGED in {k} iterations ({:.2?} wall), TC {}",
                wall,
                result.trace.tc_to_target().unwrap()
            ),
            None => {
                println!("  DID NOT CONVERGE (final err {:.3e})", result.trace.final_error());
                all_ok = false;
            }
        }

        // Cross-check: native backend must match within float noise.
        let native_solvers = (0..n)
            .map(|w| {
                Box::new(NativeSolver::new(&*problem.losses[w]))
                    as Box<dyn gadmm::runtime::LocalSolver + Send + '_>
            })
            .collect();
        let native = coordinator::train(&problem, native_solvers, rho, Chain::sequential(n), &costs, &opts);
        let (pk, nk) = (result.trace.iters_to_target(), native.trace.iters_to_target());
        println!("  backend check: PJRT {pk:?} vs native {nk:?} iterations");
        if let (Some(pk), Some(nk)) = (pk, nk) {
            let diff = (pk as i64 - nk as i64).abs();
            if diff > 2 {
                println!("  WARNING: backend iteration counts differ by {diff}");
                all_ok = false;
            }
        }
        // Note: parameter distance is not a pass/fail criterion — on the
        // ill-conditioned linreg design (κ=1e4) an objective error of 1e−4
        // still leaves long flat directions unresolved. Objective error is
        // the paper's metric and the convergence gate above.
        let dist = gadmm::linalg::vector::dist2(&result.consensus, &problem.theta_star);
        println!("  ‖consensus − θ*‖ = {dist:.3e} (informational)");
    }

    if all_ok {
        println!("\nE2E OK — three-layer stack (Pallas → JAX → HLO → PJRT → coordinator) verified.");
    } else {
        println!("\nE2E FAILED — see output above.");
        std::process::exit(1);
    }
}
